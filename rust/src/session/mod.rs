//! `DhpSession` — ONE façade from batch to iteration report.
//!
//! DHP's core claim is that the *whole* parallelism lifecycle — strategy
//! search, group reconfiguration, execution — adapts per batch (paper
//! §4–§5, Algorithm 1's per-batch loop). Before this module existed that
//! lifecycle was hand-wired at every call site: a [`Scheduler`] (or
//! baseline policy), a [`SchedulePipeline`], a budgeted
//! [`GroupPool`]/[`ParallelState`], a [`ClusterSim`], and the
//! prewarm-slack bookkeeping that makes reconfiguration charging
//! overlap-aware. [`DhpSession`] owns all of it behind two calls:
//!
//! * [`DhpSession::step`] — plan the batch into micro-batches, solve each
//!   on the background scheduling thread, **prewarm** the placed groups
//!   through the session's single communication-group pool
//!   (eviction-aware ordering), **execute** the iteration on the cluster
//!   simulator with overlap-aware reconfiguration charging, and return
//!   everything in one [`StepReport`] (schedules, iteration report,
//!   charged ≤ serial reconfiguration, replay/eviction telemetry, the
//!   fabric fingerprint the step solved under).
//! * [`DhpSession::apply`] — feed live [`MeshEvent`]s (`Occupy`/`Release`
//!   from an external resource manager — elastic co-tenancy) between
//!   steps. The session re-snapshots its authoritative mesh into the
//!   policy, the prewarm state, and the simulator, so mid-run
//!   fragmentation flows into the very next solve.
//!
//! For real trainers whose compute runs outside the simulator (the PJRT
//! loop in [`crate::train::trainer`]), [`DhpSession::prefetch`] +
//! [`DhpSession::step_prefetched`] split the step so the next batch's
//! schedule is produced on the CPU thread while the current batch's
//! gradients compute — the paper's producer–consumer overlap — with the
//! measured compute span passed back as the prewarm-overlap budget.
//!
//! Every policy (DHP and the Megatron/DeepSpeed/FlexSP baselines) drives
//! the same session machinery via the [`SchedulePolicy`] trait, so
//! policy comparisons differ ONLY in scheduling decisions.
//!
//! # Example
//!
//! ```
//! use dhp::cluster::ClusterSim;
//! use dhp::config::presets::by_name;
//! use dhp::config::{ClusterConfig, TrainStage};
//! use dhp::cost::{CostCoeffs, CostModel, HardwareSpec, MemoryModel};
//! use dhp::data::sequence::Sequence;
//! use dhp::parallel::DeviceMesh;
//! use dhp::scheduler::Scheduler;
//! use dhp::session::DhpSession;
//!
//! let cluster = ClusterConfig::default().with_npus(8);
//! let preset = by_name("InternVL3-2B").unwrap();
//! let cost = CostModel {
//!     coeffs: CostCoeffs::analytic(
//!         &preset,
//!         TrainStage::Full,
//!         &HardwareSpec::default(),
//!     ),
//!     memory: MemoryModel {
//!         e_bytes: 8192.0 * preset.act_bytes_per_token() + 1e9,
//!         m_states: 1e9,
//!         m_token: preset.act_bytes_per_token(),
//!     },
//! };
//! let scheduler = Scheduler::new(cost, DeviceMesh::new(&cluster));
//! let sim = ClusterSim::new(preset, TrainStage::Full, cluster);
//!
//! // The whole lifecycle behind one constructor...
//! let mut session = DhpSession::builder(Box::new(scheduler), sim).build();
//!
//! // ...and one call per training step.
//! let batch: Vec<Sequence> =
//!     (0..4).map(|i| Sequence::new(i, 2048 * (i + 1), 256)).collect();
//! let report = session.step(&batch);
//! assert_eq!(report.step, 0);
//! assert!(report.iteration.iter_time_s > 0.0);
//! // The overlap-charging invariant holds through the façade.
//! assert!(
//!     report.iteration.reconfig_time_s
//!         <= report.iteration.reconfig_serial_s
//! );
//! ```

use std::collections::{BTreeSet, VecDeque};
use std::hash::{Hash, Hasher};
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::baselines::{ScheduleError, SchedulePolicy};
use crate::cluster::{
    ClusterSim, CommKind, EventTimeline, FaultEvent, FaultInjector,
    IterationReport, TimedFault,
};
use crate::data::batch::GlobalBatch;
use crate::data::batch::MicroBatchPlanner;
use crate::data::sequence::Sequence;
use crate::parallel::group::GROUP_CREATE_COST_S;
use crate::parallel::mesh::DeviceMesh;
use crate::parallel::pool::{PoolCapacity, PoolStats};
use crate::parallel::{ParallelState, RankId};
use crate::scheduler::pipeline::{ScheduledBatch, SchedulePipeline};
use crate::scheduler::{FabricKind, FabricModel, Schedule};
use crate::train::CheckpointCostModel;

#[allow(unused_imports)] // doc links
use crate::parallel::GroupPool;
#[allow(unused_imports)] // doc links
use crate::scheduler::Scheduler;

mod within_step;

/// A mid-run mesh-ownership change delivered by an external resource
/// manager (elastic co-tenancy): apply between steps via
/// [`DhpSession::apply`]. Occupied ranks become invisible to placement
/// and to the fabric oracle's free-slot census from the next solve on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MeshEvent {
    /// Ranks claimed by a concurrent job or held back by the resource
    /// manager.
    Occupy(Vec<RankId>),
    /// Previously occupied ranks returned to this job.
    Release(Vec<RankId>),
}

/// Everything one training step produced, in one struct: the placed
/// schedules, the simulated iteration (with overlap-aware
/// reconfiguration charging), and the session's pool/replay telemetry.
#[derive(Debug, Clone)]
pub struct StepReport {
    /// Step index within the session (0-based, submission order).
    pub step: u64,
    /// The placed schedule of every micro-batch, in plan order.
    pub schedules: Vec<Schedule>,
    /// Number of micro-batches the batch was planned into.
    pub micro_batches: usize,
    /// Wall-clock of the full scheduling phase: micro-batch planning +
    /// submission (inside [`DhpSession::prefetch`]) plus the solve-drain
    /// and executor preparation (per-rank dispatch lists) in
    /// [`DhpSession::step_prefetched`] — Tables 1–2 "Schedule Time". Any
    /// caller compute overlapped between prefetch and execution is NOT
    /// counted. Wall-clock: excluded from [`StepReport::digest`].
    pub schedule_time_s: f64,
    /// Σ pipeline-reported scheduling latency over the micro-batches
    /// (submit → schedule ready; with [`DhpSession::prefetch`] this span
    /// runs concurrently with the caller's compute). Wall-clock:
    /// excluded from [`StepReport::digest`].
    pub schedule_latency_s: f64,
    /// Σ pure solver wall-clock over the micro-batches (packing + DP +
    /// placement), measured by the pipeline on the scheduling thread
    /// around the policy's solve call
    /// ([`crate::scheduler::pipeline::ScheduledBatch::solve_time_s`]) —
    /// the paper's "millisecond-level scheduling overhead" number.
    /// Reported on failed steps too (the refusal check still ran).
    /// Wall-clock: excluded from [`StepReport::digest`].
    pub solver_time_s: f64,
    /// Per-rank data-dispatch entries built for this step (the
    /// executor-preparation work the scheduling phase pays for).
    pub dispatch_items: usize,
    /// Micro-batches served from the exact-hit schedule cache
    /// ([`crate::scheduler::schedule_cache`]) — bit-identical reuse, no
    /// search ran. Telemetry: excluded from [`StepReport::digest`]
    /// (reuse provenance never changes semantic content).
    pub solve_cache_hits: usize,
    /// Micro-batches whose outer search ran warm-started (incumbent
    /// seeded by the re-costed previous plan, exactness-guarded).
    /// Telemetry: excluded from [`StepReport::digest`].
    pub solve_warm_starts: usize,
    /// Micro-batches that took the opt-in ε-bounded fast path (0 in
    /// every default-config run). Telemetry: excluded from
    /// [`StepReport::digest`].
    pub solve_fast_paths: usize,
    /// Mean pruned-candidate fraction over the micro-batches whose
    /// outer search actually ran (cold or warm-started; 0 when every
    /// micro-batch was a hit/fast-path). Warm starts push this up —
    /// the seeded incumbent prunes from candidate 0. Telemetry:
    /// excluded from [`StepReport::digest`].
    pub solve_pruned_frac: f64,
    /// Semantic identity of the fabric oracle this step was solved under
    /// ([`FabricModel::fingerprint`]): changes exactly when a mesh event
    /// (or any occupancy change) alters some bandwidth answer.
    pub fabric_fingerprint: u64,
    /// Groups placed across all waves of all micro-batches.
    pub groups_placed: usize,
    /// Of those, groups whose rank block replayed the previous step's
    /// placement (they key into already-pooled communicators).
    pub groups_replayed: usize,
    /// `groups_replayed / groups_placed` (0 with no groups).
    pub replay_rate: f64,
    /// The executed iteration: wave reports, exec + grad-sync time, and
    /// reconfiguration charging where `reconfig_time_s` is the
    /// non-hidden remainder `max(0, serial − slack)` and
    /// `reconfig_serial_s` covers ALL of this step's group creation
    /// (session prewarm + any execution-time re-creation).
    pub iteration: IterationReport,
    /// Mean idle fraction over the iteration's waves (Fig. 2
    /// diagnostics; 0 for an empty iteration).
    pub idle_fraction: f64,
    /// Groups evicted from the session pool during this step (0 on the
    /// default unbounded pool).
    pub evictions: u64,
    /// Cumulative pool statistics since the last
    /// [`DhpSession::reset_pool_stats`] (or session start).
    pub pool: PoolStats,
    /// Groups established in the session pool after this step.
    pub pool_groups: usize,
    /// Modeled communicator-buffer bytes those groups pin.
    pub pool_buffer_bytes: u64,
    /// Fault events the injector delivered at this step's boundary
    /// (empty without an injector — and with a quiet one).
    pub faults: Vec<FaultEvent>,
    /// `Some` when the policy could not schedule on the current mesh (a
    /// static baseline refusing a shrunken grid): nothing executed, no
    /// progress was made, and the next step retries. `None` on every
    /// successful step.
    pub failed: Option<ScheduleError>,
    /// Simulated recovery charge paid at this step's boundary:
    /// checkpoint restore + torn-group re-warm + work lost since the
    /// last checkpoint (failures), or re-warm only (preemption /
    /// straggler fencing). 0 on fault-free steps.
    pub recovery_time_s: f64,
    /// Simulated periodic-checkpoint save charge (nonzero only on steps
    /// where the checkpoint cadence fires — or, on the within-step path,
    /// where a torn checkpoint write is re-issued).
    pub checkpoint_time_s: f64,
    /// Virtual-time event log of the within-step execution kernel:
    /// wave start/finish, fault arrivals, wave interruptions, recovery
    /// stalls, checkpoint write begin/end/torn, gradient sync. Empty on
    /// the step-granular path. Only *fault-driven* records enter
    /// [`StepReport::digest`] (see
    /// [`EventTimeline::digest_into`]), so a quiet within-step run
    /// digests bit-identically to the step-granular reference.
    pub timeline: EventTimeline,
    /// Simulated compute seconds discarded to faults this step. On the
    /// step-granular path a failure replays everything since the last
    /// checkpoint (`work_since_ckpt`); on the within-step path only the
    /// interrupted partial waves (`t − wave_start`) and torn checkpoint
    /// writes are lost — completed waves persist in sharded survivor
    /// state. Comparing the two on the same fault trace is this PR's
    /// acceptance regression. Already charged inside
    /// [`StepReport::recovery_time_s`]; this field attributes it.
    pub lost_work_s: f64,
}

impl StepReport {
    /// Deterministic digest of the step's *semantic* content: placements,
    /// degrees, estimates, the iteration's simulated times, and the pool
    /// counters — everything except wall-clock measurements. Two runs of
    /// the same session inputs (same seed, same batches, same
    /// [`MeshEvent`] trace) produce bit-identical digests; the
    /// determinism regression test relies on this.
    pub fn digest(&self) -> u64 {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        self.step.hash(&mut h);
        self.fabric_fingerprint.hash(&mut h);
        self.micro_batches.hash(&mut h);
        self.dispatch_items.hash(&mut h);
        self.groups_placed.hash(&mut h);
        self.groups_replayed.hash(&mut h);
        for s in &self.schedules {
            s.est_time_s.to_bits().hash(&mut h);
            s.search_est_time_s.to_bits().hash(&mut h);
            for w in &s.waves {
                w.est_makespan_s.to_bits().hash(&mut h);
                w.replayed_groups.hash(&mut h);
                for g in &w.groups {
                    g.degree.hash(&mut h);
                    g.ranks.hash(&mut h);
                    g.seq_idxs.hash(&mut h);
                    g.est_time_s.to_bits().hash(&mut h);
                    g.ring_bw.to_bits().hash(&mut h);
                }
            }
        }
        let it = &self.iteration;
        it.tokens.hash(&mut h);
        it.exec_time_s.to_bits().hash(&mut h);
        it.grad_sync_s.to_bits().hash(&mut h);
        it.reconfig_time_s.to_bits().hash(&mut h);
        it.reconfig_serial_s.to_bits().hash(&mut h);
        it.iter_time_s.to_bits().hash(&mut h);
        it.straggle_s.to_bits().hash(&mut h);
        it.lost_work_s.to_bits().hash(&mut h);
        it.interrupted_waves.hash(&mut h);
        for w in &it.waves {
            w.makespan_s.to_bits().hash(&mut h);
            w.idle_fraction.to_bits().hash(&mut h);
            w.straggle_s.to_bits().hash(&mut h);
        }
        self.pool.hits.hash(&mut h);
        self.pool.misses.hash(&mut h);
        self.pool.evictions.hash(&mut h);
        self.pool.evicted_recreations.hash(&mut h);
        self.pool.create_time_s.to_bits().hash(&mut h);
        self.evictions.hash(&mut h);
        self.pool_groups.hash(&mut h);
        self.pool_buffer_bytes.hash(&mut h);
        self.recovery_time_s.to_bits().hash(&mut h);
        self.checkpoint_time_s.to_bits().hash(&mut h);
        self.lost_work_s.to_bits().hash(&mut h);
        self.timeline.digest_into(&mut h);
        self.faults.len().hash(&mut h);
        for f in &self.faults {
            f.digest_into(&mut h);
        }
        match &self.failed {
            None => 0u8.hash(&mut h),
            Some(e) => {
                1u8.hash(&mut h);
                e.digest_into(&mut h);
            }
        }
        h.finish()
    }

    /// Simulated wall this step actually cost the trainer: the executed
    /// iteration plus any recovery and checkpoint charges. The goodput
    /// denominator of the resilience bench (useful steps per total
    /// second).
    pub fn total_time_s(&self) -> f64 {
        self.iteration.iter_time_s + self.recovery_time_s + self.checkpoint_time_s
    }
}

/// Builder for [`DhpSession`]: policy + simulator are mandatory, every
/// budget/behavior knob has the seed default.
pub struct SessionBuilder {
    policy: Box<dyn SchedulePolicy>,
    sim: ClusterSim,
    pool_capacity: PoolCapacity,
    group_buffer_bytes: u64,
    planner: Option<MicroBatchPlanner>,
    depth: usize,
    warm_start: bool,
    injector: Option<FaultInjector>,
    ckpt_interval: u64,
    ckpt_cost: Option<CheckpointCostModel>,
    fence_threshold: Option<u32>,
    within_step: bool,
}

impl SessionBuilder {
    /// Start a session over `sim`'s cluster driven by `policy`. The
    /// simulator's mesh becomes the session's single authoritative
    /// topology: it is pushed into the policy at build time (and after
    /// every [`DhpSession::apply`]), so solver, prewarm, and execution
    /// always share one view. The cluster's configured
    /// `group_buffer_bytes` seeds the pool's buffer model.
    pub fn new(policy: Box<dyn SchedulePolicy>, sim: ClusterSim) -> Self {
        SessionBuilder {
            policy,
            group_buffer_bytes: sim.cluster.group_buffer_bytes,
            sim,
            pool_capacity: PoolCapacity::Unbounded,
            planner: None,
            depth: 2,
            warm_start: true,
            injector: None,
            ckpt_interval: 10,
            ckpt_cost: None,
            fence_threshold: None,
            within_step: false,
        }
    }

    /// Budget the session's communication-group pool (LRU eviction on
    /// overflow; default unbounded — the seed behavior).
    pub fn pool_capacity(mut self, capacity: PoolCapacity) -> Self {
        self.pool_capacity = capacity;
        self
    }

    /// Model the per-member-rank communicator buffer footprint the pool's
    /// byte accounting charges
    /// ([`crate::config::ClusterConfig::group_buffer_bytes`]).
    pub fn group_buffer_bytes(mut self, bytes: u64) -> Self {
        self.group_buffer_bytes = bytes;
        self
    }

    /// Plan each [`DhpSession::step`] batch into memory-feasible
    /// micro-batches first (the experiment-harness protocol). Without a
    /// planner the whole batch is one micro-batch (the trainer's shape).
    pub fn micro_batch_planner(mut self, planner: MicroBatchPlanner) -> Self {
        self.planner = Some(planner);
        self
    }

    /// Depth of the background scheduling pipeline's channels (how many
    /// batches may be in flight; default 2 — one step of lookahead).
    pub fn pipeline_depth(mut self, depth: usize) -> Self {
        self.depth = depth.max(1);
        self
    }

    /// Prewarm the pool from the FIRST step's schedules before executing
    /// it (the warm pool a real launch establishes before training —
    /// creation then happens outside the measured stream). Default on;
    /// the real trainer turns it off to surface step 0's creation cost.
    pub fn warm_start(mut self, warm: bool) -> Self {
        self.warm_start = warm;
        self
    }

    /// Drive the session from a seeded [`FaultInjector`]: every
    /// [`DhpSession::step`] first advances the injector one step
    /// boundary and applies its events — failures/preemptions shrink
    /// the mesh (pooled groups spanning dead ranks are invalidated, the
    /// next solve runs on the survivors), stragglers install transient
    /// per-rank slowdowns, recoveries re-admit capacity. A quiet
    /// injector is behaviorally identical to none (the zero-drift
    /// invariant the resilience bench enforces).
    pub fn fault_injector(mut self, injector: FaultInjector) -> Self {
        self.injector = Some(injector);
        self
    }

    /// Checkpoint every `steps` successful steps (default 10; 0
    /// disables). The save cost is charged to the checkpointing step's
    /// report; a later rank failure replays only the work since the
    /// last checkpoint.
    pub fn checkpoint_interval(mut self, steps: u64) -> Self {
        self.ckpt_interval = steps;
        self
    }

    /// Override the checkpoint save/restore cost model (default:
    /// [`CheckpointCostModel::for_params`] over the simulator's model
    /// preset).
    pub fn checkpoint_cost(mut self, model: CheckpointCostModel) -> Self {
        self.ckpt_cost = Some(model);
        self
    }

    /// Fence a rank out of placement once it has straggled this many
    /// times (chronic-straggler quarantine, the solver-facing half of
    /// straggler mitigation). Default off: stragglers only stretch
    /// their waves.
    pub fn straggler_fence_threshold(mut self, threshold: u32) -> Self {
        self.fence_threshold = Some(threshold.max(1));
        self
    }

    /// Feed injector draws through the discrete-event execution kernel
    /// so faults land *within* the step at a virtual arrival time: a
    /// `RankFailure` at virtual time `t` interrupts exactly the wave in
    /// flight, re-executes only that wave on its survivor plan, and
    /// charges `t − wave_start` of lost work instead of the whole-step
    /// `work_since_ckpt` replay the default boundary path charges
    /// (completed waves persist in sharded survivor state). Every
    /// [`StepReport`] then carries the virtual-time
    /// [`StepReport::timeline`]. With a quiet injector this path is
    /// digest-bit-identical to the step-granular reference — the
    /// zero-drift invariant the resilience bench enforces. Default off
    /// (boundary semantics).
    pub fn within_step_faults(mut self, on: bool) -> Self {
        self.within_step = on;
        self
    }

    /// Spawn the scheduling thread and assemble the session.
    pub fn build(self) -> DhpSession {
        let ckpt_cost = self
            .ckpt_cost
            .unwrap_or_else(|| CheckpointCostModel::for_params(self.sim.preset.params_b));
        let mesh = self.sim.mesh.clone();
        let replicas = mesh.replicas;
        let mut policy = self.policy;
        // One topology owner from the first solve on.
        policy.sync_mesh(&mesh);
        let name = policy.name();
        let comm = policy.comm_kind();
        // The policy is the single source of truth for which bandwidth
        // oracle solves run under; the session only echoes its identity.
        let fabric = policy.fabric_kind();
        // The pipeline solves only — the session owns the ONE pool, so
        // group creation is charged exactly once.
        let pipe = SchedulePipeline::spawn_policy(policy, mesh.clone(), self.depth, None);
        let mpu = ParallelState::new(mesh, 1, 1)
            .with_pool_capacity(self.pool_capacity)
            .with_group_buffer_bytes(self.group_buffer_bytes);
        DhpSession {
            pipe,
            sim: self.sim,
            mpu,
            planner: self.planner,
            fabric,
            comm,
            name,
            warm_start: self.warm_start,
            executed: 0,
            next_step: 0,
            job_seq: 0,
            prev_compute_s: 0.0,
            unsubmitted: VecDeque::new(),
            pending: VecDeque::new(),
            injector: self.injector,
            ckpt_cost,
            ckpt_interval: self.ckpt_interval,
            fence_threshold: self.fence_threshold,
            work_since_ckpt_s: 0.0,
            straggle_counts: vec![0; replicas],
            downed: BTreeSet::new(),
            fenced: BTreeSet::new(),
            pending_faults: Vec::new(),
            pending_recovery_s: 0.0,
            within_step: self.within_step,
            pending_timed: Vec::new(),
            pending_lost_work_s: 0.0,
            last_ckpt_done: None,
            pending_ckpt_write: None,
        }
    }
}

/// A batch whose scheduling is in flight (prefetched but not yet
/// executed).
struct PendingStep {
    step: u64,
    first_job: u64,
    mbs: Vec<Vec<Sequence>>,
    received: Vec<ScheduledBatch>,
    /// Scheduling-phase wall-clock already spent on this step inside
    /// `prefetch` (micro-batch planning + submission). The drain span in
    /// `step_prefetched` is added on top — the caller's own compute
    /// between the two calls is deliberately NOT counted.
    sched_span_s: f64,
}

/// The session façade: owns the mesh, the scheduling pipeline, the
/// communication-group pool, and the cluster simulator for one training
/// run. See the [module docs](self) for the lifecycle it unifies.
pub struct DhpSession {
    pipe: SchedulePipeline,
    sim: ClusterSim,
    /// Authoritative mesh + the run's single group pool.
    mpu: ParallelState,
    planner: Option<MicroBatchPlanner>,
    fabric: FabricKind,
    comm: CommKind,
    name: &'static str,
    warm_start: bool,
    /// Steps executed so far (warm start applies to the first).
    executed: u64,
    /// Next step index to assign at prefetch time.
    next_step: u64,
    /// Next pipeline job id (one job per micro-batch, FIFO).
    job_seq: u64,
    /// Previous step's simulated compute (exec + grad sync) — the
    /// default prewarm-overlap budget for [`DhpSession::step`].
    prev_compute_s: f64,
    /// Micro-batch jobs not yet accepted by the pipeline's bounded
    /// channel, pumped opportunistically (deadlock-free submission).
    unsubmitted: VecDeque<(u64, Vec<Sequence>)>,
    /// Prefetched steps awaiting execution, oldest first.
    pending: VecDeque<PendingStep>,
    /// Per-step fault-trace source (None = no faults ever).
    injector: Option<FaultInjector>,
    /// Checkpoint save/restore cost model (recovery accounting).
    ckpt_cost: CheckpointCostModel,
    /// Checkpoint every this many successful steps (0 disables).
    ckpt_interval: u64,
    /// Fence ranks after this many straggle events (None = never).
    fence_threshold: Option<u32>,
    /// Simulated seconds of progress since the last checkpoint — the
    /// work a rank failure replays.
    work_since_ckpt_s: f64,
    /// Per-rank straggle-event counts (chronic-offender detection).
    straggle_counts: Vec<u32>,
    /// Ranks currently lost to failures or preemption; their `Recovery`
    /// re-admits exactly these.
    downed: BTreeSet<RankId>,
    /// Ranks permanently fenced off as chronic stragglers (never
    /// re-admitted by `Recovery`).
    fenced: BTreeSet<RankId>,
    /// Fault events applied at the upcoming step's boundary, attached
    /// to its report when it executes.
    pending_faults: Vec<FaultEvent>,
    /// Recovery charge accrued at the upcoming step's boundary.
    pending_recovery_s: f64,
    /// Route injector draws through the discrete-event kernel
    /// ([`SessionBuilder::within_step_faults`]).
    within_step: bool,
    /// Within-step mode: timed fault draws for the upcoming step,
    /// stashed at the boundary and delivered to the event kernel at
    /// execution time (canonical arrival order).
    pending_timed: Vec<TimedFault>,
    /// Lost-work attribution accrued at the upcoming step's boundary
    /// (the `work_since_ckpt` replay a boundary-mode failure charges;
    /// already inside `pending_recovery_s` — attribution only).
    pending_lost_work_s: f64,
    /// Id (checkpointing step index) of the last checkpoint whose write
    /// COMPLETED on the virtual timeline — what a torn write falls back
    /// to. Within-step mode only.
    last_ckpt_done: Option<u64>,
    /// An open checkpoint write window `(id, write_seconds)`: the save
    /// the cadence issued at the end of a step physically writes during
    /// the NEXT step's virtual timeline, where a failure can tear it.
    /// Within-step mode only.
    pending_ckpt_write: Option<(u64, f64)>,
}

impl DhpSession {
    /// Start building a session (see [`SessionBuilder::new`]).
    pub fn builder(policy: Box<dyn SchedulePolicy>, sim: ClusterSim) -> SessionBuilder {
        SessionBuilder::new(policy, sim)
    }

    /// Display name of the driving policy ("DHP", "Megatron-LM", …).
    pub fn policy_name(&self) -> &'static str {
        self.name
    }

    /// Communication pattern the policy's groups execute with.
    pub fn comm_kind(&self) -> CommKind {
        self.comm
    }

    /// The session's authoritative mesh (occupancy reflects every applied
    /// [`MeshEvent`]).
    pub fn mesh(&self) -> &DeviceMesh {
        &self.mpu.mesh
    }

    /// Ranks currently lost to rank failures or co-tenant preemption
    /// (fault-injector driven; empty without an injector).
    pub fn downed_ranks(&self) -> Vec<RankId> {
        self.downed.iter().copied().collect()
    }

    /// Ranks permanently fenced out of placement as chronic stragglers.
    pub fn fenced_ranks(&self) -> Vec<RankId> {
        self.fenced.iter().copied().collect()
    }

    /// True when no prefetched or unsubmitted step is in flight — i.e.
    /// [`DhpSession::apply`] is legal right now. Multi-session drivers
    /// (the cluster service interleaving N sessions on one mesh) check
    /// this before delivering occupancy events so they never trip the
    /// between-steps precondition or deadlock the bounded pipeline
    /// channels mid-prefetch.
    pub fn is_idle(&self) -> bool {
        self.pending.is_empty() && self.unsubmitted.is_empty()
    }

    /// Number of prefetched steps currently in flight (submitted to the
    /// background pipeline but not yet retired by
    /// [`DhpSession::step_prefetched`]).
    pub fn pending_steps(&self) -> usize {
        self.pending.len()
    }

    /// Cumulative pool statistics since the last
    /// [`DhpSession::reset_pool_stats`].
    pub fn pool_stats(&self) -> PoolStats {
        self.mpu.pool_stats()
    }

    /// Groups currently established in the session pool.
    pub fn pool_groups(&self) -> usize {
        self.mpu.pool_size()
    }

    /// Modeled communicator-buffer bytes the pool currently pins.
    pub fn pool_buffer_bytes(&self) -> u64 {
        self.mpu.pool_buffer_bytes()
    }

    /// Zero the pool's traffic counters while keeping the cached groups
    /// (the measured-window boundary of the paper's protocol).
    pub fn reset_pool_stats(&mut self) {
        self.mpu.pool_mut().reset_stats();
    }

    /// Threads ever spawned by the scheduling pipeline's persistent
    /// outer-search pool ([`crate::scheduler::SearchPool`]). All workers
    /// are spawned when the session is built; this value must stay
    /// constant across `step()` calls — the steady-state zero-spawn
    /// guarantee of the persistent-pool design.
    pub fn search_threads_spawned(&self) -> usize {
        self.pipe.search_pool().threads_spawned()
    }

    /// Semantic identity of the fabric oracle the NEXT solve runs under
    /// ([`FabricModel::fingerprint`]): mesh events that change any
    /// bandwidth answer change this value.
    pub fn fabric_fingerprint(&self) -> u64 {
        match self.fabric {
            FabricKind::Uniform => FabricModel::uniform(&self.mpu.mesh).fingerprint(),
            FabricKind::MeshBacked => {
                FabricModel::mesh_backed(&self.mpu.mesh, None).fingerprint()
            }
        }
    }

    /// Submit as many queued micro-batch jobs as the pipeline's bounded
    /// channel accepts right now (never blocks — the submit/recv
    /// interleaving in [`DhpSession::step_prefetched`] guarantees
    /// progress for batches of any size at any pipeline depth).
    fn pump(&mut self) {
        while let Some((id, seqs)) = self.unsubmitted.pop_front() {
            if let Err(seqs) = self.pipe.try_submit(id, seqs) {
                self.unsubmitted.push_front((id, seqs));
                break;
            }
        }
    }

    /// Commit a fault-driven occupancy change to every topology
    /// consumer — the authoritative mesh, the simulator, and (through
    /// the ordered pipeline control channel) the scheduling policy —
    /// and tear pooled groups spanning newly occupied ranks. Returns
    /// how many groups were torn down (the re-warm charge base).
    fn commit_occupancy(&mut self, occupy: &[RankId], release: &[RankId]) -> usize {
        let mut mesh = self.mpu.mesh.clone();
        if !occupy.is_empty() {
            mesh.occupy(occupy);
        }
        if !release.is_empty() {
            mesh.release(release);
        }
        self.mpu.mesh = mesh.clone();
        self.sim.mesh = mesh.clone();
        self.pipe.sync_mesh(mesh);
        if occupy.is_empty() {
            0
        } else {
            self.mpu.pool_mut().invalidate_ranks(occupy)
        }
    }

    /// True if `rank` can be taken away right now: in range, currently
    /// free to this job, and not the last free replica (a job with zero
    /// replicas is a different experiment, not a degraded run).
    fn take_down(&self, rank: RankId) -> bool {
        rank < self.mpu.mesh.replicas
            && self.mpu.mesh.is_rank_free(rank)
            && self.mpu.mesh.free_replicas() > 1
    }

    /// Advance the fault injector to the next step boundary and apply
    /// its events: recoveries re-admit downed ranks, failures and
    /// preemptions shrink the mesh (charging restore / lost-work /
    /// re-warm into the step's recovery time), stragglers install
    /// transient slowdowns — or, past the fence threshold, quarantine
    /// the offender out of placement. Events the live mesh makes
    /// impossible (dead-rank double-kill, last-rank kill, out-of-range
    /// scripted ranks) are skipped, never panicked on. The events and
    /// the accrued charge ride on the next executed step's report.
    fn apply_faults(&mut self) {
        // Straggler slowdowns are transient: one step only.
        self.sim.clear_slowdowns();
        let mut injector = match self.injector.take() {
            Some(injector) => injector,
            None => return,
        };
        if self.within_step {
            // Within-step mode: nothing is applied at the boundary — the
            // draws (with virtual arrival times) are stashed for the
            // event kernel, which applies each fault's state change at
            // its arrival instant during execution. The schedule solves
            // on the PRE-fault mesh (the fault has not happened yet when
            // the solve runs); the NEXT step's solve sees the survivors.
            let timed = injector.advance_timed(self.next_step);
            self.injector = Some(injector);
            self.pending_faults = timed.iter().map(|t| t.event.clone()).collect();
            self.pending_timed = timed;
            return;
        }
        let events = injector.advance(self.next_step);
        self.injector = Some(injector);
        let mut recovery = 0.0;
        for ev in &events {
            match ev {
                FaultEvent::Recovery { ranks } => {
                    // Re-admit only ranks THIS machinery downed and that
                    // are still occupied (a mesh event may have released
                    // them already); fenced ranks stay fenced.
                    let back: Vec<RankId> = ranks
                        .iter()
                        .copied()
                        .filter(|&r| {
                            self.downed.remove(&r) && !self.mpu.mesh.is_rank_free(r)
                        })
                        .collect();
                    if !back.is_empty() {
                        self.commit_occupancy(&[], &back);
                    }
                }
                FaultEvent::RankFailure { rank } => {
                    if self.take_down(*rank) {
                        let torn = self.commit_occupancy(&[*rank], &[]);
                        self.downed.insert(*rank);
                        // A failure loses device state: restore the last
                        // checkpoint, re-warm the torn groups, redo the
                        // work since that checkpoint.
                        recovery += self.ckpt_cost.restore_time_s()
                            + torn as f64 * GROUP_CREATE_COST_S
                            + self.work_since_ckpt_s;
                        self.pending_lost_work_s += self.work_since_ckpt_s;
                        self.work_since_ckpt_s = 0.0;
                        // No compute span survives a restore to hide the
                        // next step's prewarm behind.
                        self.prev_compute_s = 0.0;
                    }
                }
                FaultEvent::Preemption { ranks, .. } => {
                    for &r in ranks {
                        if self.take_down(r) {
                            let torn = self.commit_occupancy(&[r], &[]);
                            self.downed.insert(r);
                            // No state lost: the job shrinks and only
                            // re-warms what the leaving ranks tore.
                            recovery += torn as f64 * GROUP_CREATE_COST_S;
                        }
                    }
                }
                FaultEvent::Straggler { rank, slowdown } => {
                    let r = *rank;
                    if r >= self.mpu.mesh.replicas || !self.mpu.mesh.is_rank_free(r) {
                        continue;
                    }
                    self.straggle_counts[r] += 1;
                    let chronic = match self.fence_threshold {
                        Some(t) => self.straggle_counts[r] >= t,
                        None => false,
                    };
                    if chronic && self.mpu.mesh.free_replicas() > 1 {
                        // Quarantine the chronic offender: placement
                        // stops seeing it, as if a co-tenant occupied it
                        // for good.
                        let torn = self.commit_occupancy(&[r], &[]);
                        self.fenced.insert(r);
                        recovery += torn as f64 * GROUP_CREATE_COST_S;
                    } else {
                        self.sim.set_slowdown(r, *slowdown);
                    }
                }
            }
        }
        self.pending_faults = events;
        self.pending_recovery_s = recovery;
    }

    /// Hand the next batch to the background scheduling thread WITHOUT
    /// waiting for the result — the real trainer calls this before
    /// computing the current step's gradients, so scheduling latency
    /// hides behind compute (paper §5's producer–consumer overlap).
    /// Execute it later with [`DhpSession::step_prefetched`]; prefetched
    /// steps execute in submission order.
    pub fn prefetch(&mut self, seqs: &[Sequence]) {
        let t0 = Instant::now();
        let step = self.next_step;
        self.next_step += 1;
        let mbs: Vec<Vec<Sequence>> = match &self.planner {
            Some(planner) => planner
                .plan(&GlobalBatch {
                    step,
                    sequences: seqs.to_vec(),
                })
                .into_iter()
                .map(|mb| mb.sequences)
                .collect(),
            None => vec![seqs.to_vec()],
        };
        let first_job = self.job_seq;
        for mb in &mbs {
            self.unsubmitted.push_back((self.job_seq, mb.clone()));
            self.job_seq += 1;
        }
        self.pending.push_back(PendingStep {
            step,
            first_job,
            mbs,
            received: Vec::new(),
            sched_span_s: 0.0,
        });
        self.pump();
        if let Some(pending) = self.pending.back_mut() {
            pending.sched_span_s = t0.elapsed().as_secs_f64();
        }
    }

    /// Run one full training step: schedule → prewarm → execute →
    /// report. The prewarm-overlap budget is the previous step's
    /// simulated compute (exec + grad sync), matching the experiment
    /// protocol; step 0 has nothing to hide behind. Panics if batches
    /// are still pending from [`DhpSession::prefetch`] — drain those
    /// with [`DhpSession::step_prefetched`] first.
    pub fn step(&mut self, seqs: &[Sequence]) -> StepReport {
        let slack = self.prev_compute_s;
        self.step_overlapped(seqs, slack)
    }

    /// [`DhpSession::step`] with a caller-supplied prewarm-overlap budget
    /// (e.g. a real trainer's measured compute span). Reconfiguration is
    /// charged `max(0, serial − slack)`.
    pub fn step_overlapped(&mut self, seqs: &[Sequence], prewarm_slack_s: f64) -> StepReport {
        assert!(
            self.pending.is_empty(),
            "{} prefetched batch(es) pending — drain them with step_prefetched() \
             before calling step()",
            self.pending.len()
        );
        // Faults land at the step boundary, BEFORE the solve: the
        // schedule must see the post-fault mesh.
        self.apply_faults();
        self.prefetch(seqs);
        self.step_prefetched(prewarm_slack_s)
            .expect("a batch was just prefetched")
    }

    /// Execute the OLDEST prefetched batch (`None` if nothing is
    /// prefetched): wait for its schedules, prewarm their groups through
    /// the session pool (eviction-aware ordering), execute the iteration
    /// on the simulator, and charge reconfiguration
    /// `max(0, serial − prewarm_slack_s)` — `prewarm_slack_s` is the
    /// compute span the caller overlapped the prepare with (a real
    /// trainer passes its previous step's measured compute).
    pub fn step_prefetched(&mut self, prewarm_slack_s: f64) -> Option<StepReport> {
        let mut pending = self.pending.pop_front()?;
        let t_drain = Instant::now();
        // Drain this step's schedules, re-pumping submissions as channel
        // capacity frees up (deadlock-free for any micro-batch count).
        while pending.received.len() < pending.mbs.len() {
            self.pump();
            let sb = self.pipe.recv().expect("scheduler pipeline closed");
            debug_assert_eq!(
                sb.step,
                pending.first_job + pending.received.len() as u64,
                "pipeline results out of order"
            );
            pending.received.push(sb);
        }
        // Keep any later prefetched step flowing in the background.
        self.pump();

        // Boundary faults (if any) ride on this step's report; in
        // within-step mode the timed draws instead flow into the event
        // kernel below and the boundary charges are zero.
        let faults = std::mem::take(&mut self.pending_faults);
        let recovery_time_s = std::mem::take(&mut self.pending_recovery_s);
        let timed = std::mem::take(&mut self.pending_timed);
        let boundary_lost_s = std::mem::take(&mut self.pending_lost_work_s);

        let schedule_latency_s: f64 =
            pending.received.iter().map(|b| b.schedule_latency_s).sum();
        // Pipeline-measured pure solve wall time, summed over the
        // micro-batches. Measured on the scheduling thread around the
        // policy call, so it is meaningful even for batches the policy
        // refused (the failed-step path below reports it too).
        let solver_time_s: f64 =
            pending.received.iter().map(|b| b.solve_time_s).sum();
        // Cross-step reuse telemetry, aggregated over the micro-batches
        // that produced a schedule (computed before the drain below
        // consumes `received`, so the failed-step report carries it too).
        let (mut solve_cache_hits, mut solve_warm_starts, mut solve_fast_paths) =
            (0usize, 0usize, 0usize);
        let (mut pruned_sum, mut searched_mbs) = (0.0f64, 0usize);
        for sb in &pending.received {
            if let Ok(s) = &sb.schedule {
                solve_cache_hits += s.stats.cache_hit as usize;
                solve_warm_starts += s.stats.warm_started as usize;
                solve_fast_paths += s.stats.fast_path as usize;
                if s.stats.candidates > 0 {
                    pruned_sum += s.stats.pruned_frac();
                    searched_mbs += 1;
                }
            }
        }
        let solve_pruned_frac = if searched_mbs == 0 {
            0.0
        } else {
            pruned_sum / searched_mbs as f64
        };
        let n_mbs = pending.mbs.len();
        let mut failed: Option<ScheduleError> = None;
        let mut scheduled: Vec<(Vec<Sequence>, Schedule)> = Vec::with_capacity(n_mbs);
        for (mb, sb) in pending.mbs.into_iter().zip(pending.received.into_iter()) {
            match sb.schedule {
                Ok(schedule) => scheduled.push((mb, schedule)),
                Err(err) => {
                    if failed.is_none() {
                        failed = Some(err);
                    }
                }
            }
        }
        if let Some(err) = failed {
            // A static policy that cannot fit the shrunken mesh reports
            // a typed failed step instead of panicking: nothing
            // executes, no progress is made, and the next step retries
            // at whatever strength the mesh then offers. An iteration
            // cannot half-run (gradient sync needs every micro-batch),
            // so any schedule that did solve is discarded untouched.
            let schedule_time_s = pending.sched_span_s + t_drain.elapsed().as_secs_f64();
            self.prev_compute_s = 0.0;
            // Within-step mode: nothing executes, so there is no virtual
            // timeline to land the faults on — apply their state changes
            // degenerately at t = 0 (the charges must not be lost or the
            // next solve would see a stale mesh). An open checkpoint
            // write window stays pending: the write makes no progress
            // while nothing executes.
            let (timeline, degenerate_recovery_s) =
                self.apply_timed_faults_degenerate(&timed);
            return Some(StepReport {
                step: pending.step,
                schedules: Vec::new(),
                micro_batches: n_mbs,
                schedule_time_s,
                schedule_latency_s,
                solver_time_s,
                dispatch_items: 0,
                solve_cache_hits,
                solve_warm_starts,
                solve_fast_paths,
                solve_pruned_frac,
                fabric_fingerprint: self.fabric_fingerprint(),
                groups_placed: 0,
                groups_replayed: 0,
                replay_rate: 0.0,
                iteration: IterationReport {
                    waves: Vec::new(),
                    exec_time_s: 0.0,
                    grad_sync_s: 0.0,
                    reconfig_time_s: 0.0,
                    reconfig_serial_s: 0.0,
                    iter_time_s: 0.0,
                    straggle_s: 0.0,
                    tokens: 0,
                    lost_work_s: 0.0,
                    interrupted_waves: 0,
                },
                idle_fraction: 0.0,
                evictions: 0,
                pool: self.mpu.pool_stats(),
                pool_groups: self.mpu.pool_size(),
                pool_buffer_bytes: self.mpu.pool_buffer_bytes(),
                faults,
                failed: Some(err),
                recovery_time_s: recovery_time_s + degenerate_recovery_s,
                checkpoint_time_s: 0.0,
                timeline,
                lost_work_s: boundary_lost_s,
            });
        }
        // Executor preparation is part of the scheduling phase: per-rank
        // data dispatch lists.
        let mut dispatch_items = 0usize;
        for (seqs, schedule) in &scheduled {
            for plan in &schedule.waves {
                dispatch_items += dispatch(seqs, plan).len();
            }
        }
        // Scheduling phase = the prefetch span (planning + submission)
        // plus this drain + executor-preparation span. Compute the
        // caller overlapped between prefetch and this call is NOT
        // scheduling time.
        let schedule_time_s = pending.sched_span_s + t_drain.elapsed().as_secs_f64();

        if self.executed == 0 && self.warm_start {
            // The warm pool a real launch establishes before training:
            // creation happens before the measured stream (prewarm also
            // zeroes the traffic counters).
            self.mpu
                .pool_mut()
                .prewarm(scheduled.iter().flat_map(|(_, s)| s.pool_keys()));
        }
        let stats_before = self.mpu.pool_stats();
        // Prewarm every wave through the session pool (reverse-wave order
        // under a capacity cap, so the groups needed soonest stay
        // LRU-warmest). A schedule the policy just validated cannot fail
        // placement checks; a failure here is a policy bug.
        for (_, schedule) in &scheduled {
            self.mpu
                .prepare_schedule(schedule)
                .expect("policy emitted an invalid placement");
        }
        let prewarm_serial_s =
            self.mpu.pool_stats().create_time_s - stats_before.create_time_s;
        // Execute with slack 0 — the session charges overlap itself,
        // against the TOTAL serial cost (prewarm + any execution-time
        // re-creation a tight pool cap forces). Execution re-touches the
        // groups the prepare just acquired, so it runs in passive-hit
        // mode: pool traffic counts ONE acquisition per group per step
        // (hit-rates stay comparable with the prepare-less seed
        // accounting) while an eviction-forced re-creation still counts
        // as a charged miss.
        self.mpu.pool_mut().set_passive_hits(true);
        let (mut iteration, timeline, within_recovery_s, torn_ckpt, had_failure) =
            if self.within_step {
                let out = self.execute_within_step(&scheduled, &timed);
                (
                    out.iteration,
                    out.timeline,
                    out.recovery_s,
                    out.torn_ckpt,
                    out.had_failure,
                )
            } else {
                let pool = self.mpu.pool_mut();
                let iteration = self.sim.execute_iteration_overlapped(
                    &scheduled,
                    self.comm,
                    pool,
                    0.0,
                );
                (iteration, EventTimeline::new(), 0.0, None, false)
            };
        self.mpu.pool_mut().set_passive_hits(false);
        let serial = prewarm_serial_s + iteration.reconfig_serial_s;
        let charged = (serial - prewarm_slack_s.max(0.0)).max(0.0);
        iteration.reconfig_serial_s = serial;
        iteration.reconfig_time_s = charged;
        iteration.iter_time_s = iteration.exec_time_s + iteration.grad_sync_s + charged;
        self.prev_compute_s = iteration.exec_time_s + iteration.grad_sync_s;
        if had_failure {
            // Same rule as the boundary path: no compute span survives a
            // mid-step restore to hide the next step's prewarm behind.
            self.prev_compute_s = 0.0;
        }
        self.executed += 1;
        // This step's progress is at risk until the next checkpoint; the
        // cadence is injector-independent so a fault-free faulted run
        // and a no-injector run stay bit-identical.
        self.work_since_ckpt_s += iteration.iter_time_s;
        let cadence =
            self.ckpt_interval > 0 && self.executed % self.ckpt_interval == 0;
        let checkpoint_time_s = if cadence {
            self.work_since_ckpt_s = 0.0;
            let save = self.ckpt_cost.save_time_s();
            if self.within_step {
                // The save issued now physically writes during the NEXT
                // step's virtual timeline, where a failure can tear it.
                self.pending_ckpt_write = Some((pending.step, save));
            }
            save
        } else if let Some(torn_id) = torn_ckpt {
            // A failure tore this step's in-flight checkpoint write:
            // re-issue the save (charged again — the first charge bought
            // a write that never completed) with the same id; it opens a
            // fresh window over the next step.
            let save = self.ckpt_cost.save_time_s();
            self.pending_ckpt_write = Some((torn_id, save));
            save
        } else {
            0.0
        };

        let (mut groups_placed, mut groups_replayed) = (0usize, 0usize);
        for (_, s) in &scheduled {
            for w in &s.waves {
                groups_placed += w.groups.len();
                groups_replayed += w.replayed_groups;
            }
        }
        let idle_fraction = if iteration.waves.is_empty() {
            0.0
        } else {
            iteration.waves.iter().map(|w| w.idle_fraction).sum::<f64>()
                / iteration.waves.len() as f64
        };
        let pool_stats = self.mpu.pool_stats();
        let schedules: Vec<Schedule> = scheduled.into_iter().map(|(_, s)| s).collect();
        Some(StepReport {
            step: pending.step,
            micro_batches: schedules.len(),
            schedule_time_s,
            schedule_latency_s,
            solver_time_s,
            dispatch_items,
            solve_cache_hits,
            solve_warm_starts,
            solve_fast_paths,
            solve_pruned_frac,
            fabric_fingerprint: self.fabric_fingerprint(),
            groups_placed,
            groups_replayed,
            replay_rate: if groups_placed == 0 {
                0.0
            } else {
                groups_replayed as f64 / groups_placed as f64
            },
            idle_fraction,
            evictions: pool_stats.evictions - stats_before.evictions,
            pool: pool_stats,
            pool_groups: self.mpu.pool_size(),
            pool_buffer_bytes: self.mpu.pool_buffer_bytes(),
            lost_work_s: boundary_lost_s + iteration.lost_work_s,
            iteration,
            schedules,
            faults,
            failed: None,
            recovery_time_s: recovery_time_s + within_recovery_s,
            checkpoint_time_s,
            timeline,
        })
    }

    /// Apply a live mesh-event trace between steps (the ROADMAP "live
    /// occupancy feed"): validate the whole trace against a scratch
    /// mesh — an invalid trace leaves the session untouched — then
    /// commit it to the session's mesh, the simulator, and (through the
    /// ordered pipeline control channel) the scheduling policy, so the
    /// next solve prices the new fragmentation.
    ///
    /// Errors if batches are still prefetched (their schedules would mix
    /// old and new topology), on out-of-range ranks, on occupying an
    /// already-occupied rank (or releasing a free one), or if the trace
    /// would leave zero free replicas.
    pub fn apply(&mut self, events: &[MeshEvent]) -> Result<()> {
        ensure!(
            self.pending.is_empty() && self.unsubmitted.is_empty(),
            "apply() must run between steps: {} prefetched batch(es) still pending",
            self.pending.len()
        );
        let mut mesh = self.mpu.mesh.clone();
        for (i, event) in events.iter().enumerate() {
            match event {
                MeshEvent::Occupy(ranks) => {
                    for &r in ranks {
                        ensure!(
                            r < mesh.replicas,
                            "event {i}: occupy rank {r} out of range \
                             (mesh has {} replicas)",
                            mesh.replicas
                        );
                        ensure!(
                            mesh.is_rank_free(r),
                            "event {i}: occupy rank {r} — already occupied"
                        );
                        mesh.occupy(&[r]);
                    }
                }
                MeshEvent::Release(ranks) => {
                    for &r in ranks {
                        ensure!(
                            r < mesh.replicas,
                            "event {i}: release rank {r} out of range \
                             (mesh has {} replicas)",
                            mesh.replicas
                        );
                        ensure!(
                            !mesh.is_rank_free(r),
                            "event {i}: release rank {r} — not occupied"
                        );
                        mesh.release(&[r]);
                    }
                }
            }
        }
        ensure!(
            mesh.free_replicas() > 0,
            "mesh-event trace leaves no free replicas to schedule onto"
        );
        // A communicator spanning a surrendered rank is invalid the
        // moment the co-tenant takes it: tear those groups down so the
        // pool's residency and buffer accounting never report phantom
        // footprint on devices this job no longer owns (and a
        // BufferBytes budget is not consumed by dead groups).
        // "Surrendered" is the NET free→occupied transition across the
        // whole trace — the same rule the pipeline's owned-pool
        // SyncMesh path applies — so a trace that occupies and releases
        // the same rank is a topology no-op and tears nothing down.
        let surrendered: Vec<RankId> = (0..mesh.replicas)
            .filter(|&r| !mesh.is_rank_free(r) && self.mpu.mesh.is_rank_free(r))
            .collect();
        self.mpu.mesh = mesh.clone();
        self.sim.mesh = mesh.clone();
        self.pipe.sync_mesh(mesh);
        if !surrendered.is_empty() {
            self.mpu.pool_mut().invalidate_ranks(&surrendered);
        }
        Ok(())
    }

    /// Close the submission side and join the scheduling thread
    /// (dropping the session does the same).
    pub fn shutdown(self) {
        self.pipe.shutdown();
    }
}

/// Per-rank data-dispatch entry: which contiguous token range of which
/// sequence a rank receives under ring CP (the executor's reallocation
/// step in Fig. 3; its construction cost is real scheduling-phase work).
#[derive(Debug, Clone, PartialEq)]
pub struct DispatchEntry {
    /// Index of the group within its placed plan.
    pub group_idx: usize,
    /// Slot within the group's placed rank set.
    pub rank_slot: usize,
    /// Index into the micro-batch's sequence list.
    pub seq_idx: usize,
    /// First token (inclusive) of this rank's contiguous chunk.
    pub token_start: u64,
    /// One past the last token of this rank's chunk.
    pub token_end: u64,
}

/// Build the per-rank dispatch list for one placed plan: each sequence is
/// split into `degree` contiguous chunks (CP's even sequence
/// partitioning). `rank_slot` indexes into the group's placed rank set.
pub fn dispatch(
    seqs: &[Sequence],
    plan: &crate::scheduler::PlacedPlan,
) -> Vec<DispatchEntry> {
    let mut out = Vec::new();
    for (gi, g) in plan.groups.iter().enumerate() {
        let d = g.degree as u64;
        for &si in &g.seq_idxs {
            let len = seqs[si].len();
            let chunk = len.div_ceil(d);
            for slot in 0..g.degree {
                let start = slot as u64 * chunk;
                if start >= len {
                    break;
                }
                let end = (start + chunk).min(len);
                out.push(DispatchEntry {
                    group_idx: gi,
                    rank_slot: slot,
                    seq_idx: si,
                    token_start: start,
                    token_end: end,
                });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::by_name;
    use crate::config::{ClusterConfig, TrainStage};
    use crate::cost::{CostCoeffs, CostModel, HardwareSpec, MemoryModel};
    use crate::data::datasets::{DatasetKind, DatasetSampler, TokenizerSpec};
    use crate::scheduler::Scheduler;

    /// High-res video tokenization (the long-context regime where mixed
    /// CP degrees pay off).
    fn sampler(kind: DatasetKind, seed: u64) -> DatasetSampler {
        DatasetSampler::new(kind, seed).with_spec(TokenizerSpec {
            fps: 2.0,
            tokens_per_frame: 256.0,
            text_min: 32,
            text_max: 512,
        })
    }

    /// Paper regime: one replica = TP×PP = 4 NPUs, 2 replicas/node — CP
    /// degrees ≥ 3 cross nodes, so occupancy changes flip locality.
    fn paper_regime(replicas: usize) -> (CostModel, ClusterConfig) {
        let mut cluster = ClusterConfig::default().with_npus(replicas * 4);
        cluster.tp = 2;
        cluster.pp = 2;
        let preset = by_name("InternVL3-8B").unwrap();
        let hw = HardwareSpec {
            peak_flops: 376e12 * 4.0,
            ..HardwareSpec::default()
        };
        let cost = CostModel {
            coeffs: CostCoeffs::analytic(&preset, TrainStage::Full, &hw),
            memory: MemoryModel {
                e_bytes: 8192.0 * preset.act_bytes_per_token() + 2e9,
                m_states: 2e9,
                m_token: preset.act_bytes_per_token(),
            },
        };
        (cost, cluster)
    }

    fn dhp_builder(replicas: usize) -> SessionBuilder {
        let (cost, cluster) = paper_regime(replicas);
        let preset = by_name("InternVL3-8B").unwrap();
        let scheduler = Scheduler::new(cost, crate::parallel::DeviceMesh::new(&cluster));
        let sim = ClusterSim::new(preset, TrainStage::Full, cluster);
        DhpSession::builder(Box::new(scheduler), sim)
    }

    fn dhp_session(replicas: usize) -> DhpSession {
        dhp_builder(replicas).build()
    }

    fn megatron_builder(replicas: usize) -> SessionBuilder {
        let (cost, cluster) = paper_regime(replicas);
        let preset = by_name("InternVL3-8B").unwrap();
        let policy =
            crate::baselines::MegatronStaticCp::new(2, replicas, cost, 12.5e9);
        let sim = ClusterSim::new(preset, TrainStage::Full, cluster);
        DhpSession::builder(Box::new(policy), sim)
    }

    #[test]
    fn steady_state_steps_never_spawn_search_threads() {
        // ISSUE-7 acceptance: the outer search runs on the pipeline's
        // persistent pool, so all search threads exist before the first
        // step and the spawn counter never moves across steady-state
        // `step()` calls.
        let mut session = dhp_session(8);
        let mut sampler = sampler(DatasetKind::OpenVid, 0x9001);
        let first = session.step(&sampler.sample_batch(24));
        assert!(first.failed.is_none());
        let spawned = session.search_threads_spawned();
        let mut solver_total = 0.0;
        for _ in 0..10 {
            let report = session.step(&sampler.sample_batch(24));
            assert!(report.failed.is_none());
            solver_total += report.solver_time_s;
            assert_eq!(
                session.search_threads_spawned(),
                spawned,
                "a steady-state step spawned a search thread"
            );
        }
        // The pipeline-measured solver time is real wall clock: ten
        // planned-and-executed steps cannot take literally zero time.
        assert!(
            solver_total > 0.0,
            "solver_time_s never measured anything across 10 steps"
        );
    }

    #[test]
    fn mid_run_occupy_reshapes_the_next_solve() {
        // The ISSUE-5 acceptance test: a mid-run Occupy changes the
        // fabric fingerprint, subsequent schedules avoid the occupied
        // ranks, and the per-step telemetry survives the façade.
        let mut session = dhp_session(8); // 8 replicas, 2 per node
        let mut sampler = sampler(DatasetKind::Msrvtt, 0x0CC);
        let batch = sampler.sample_batch(24);

        let r0 = session.step(&batch);
        let fp0 = r0.fabric_fingerprint;
        assert!(r0.iteration.iter_time_s > 0.0);

        // One rank of EVERY node: the largest per-node free count drops
        // 2 → 1, so intra-node locality answers change.
        let occupied: Vec<usize> = (0..8).filter(|r| r % 2 == 0).collect();
        session
            .apply(&[MeshEvent::Occupy(occupied.clone())])
            .unwrap();
        assert_ne!(
            session.fabric_fingerprint(),
            fp0,
            "locality-changing occupancy must re-key the fabric oracle"
        );
        assert_eq!(session.mesh().free_replicas(), 4);

        let r1 = session.step(&batch);
        assert_ne!(r1.fabric_fingerprint, fp0);
        for schedule in &r1.schedules {
            for wave in &schedule.waves {
                for g in &wave.groups {
                    for &r in &g.ranks {
                        assert!(
                            !occupied.contains(&r),
                            "rank {r} placed while occupied"
                        );
                    }
                }
            }
        }
        // Telemetry is preserved through the façade.
        assert!(
            r1.iteration.reconfig_time_s <= r1.iteration.reconfig_serial_s + 1e-15,
            "charged must never exceed serial"
        );
        assert!((0.0..=1.0).contains(&r1.replay_rate));
        assert_eq!(r1.evictions, 0, "unbounded session pools never evict");

        // Release restores the original oracle identity and full budget.
        session.apply(&[MeshEvent::Release(occupied)]).unwrap();
        assert_eq!(session.fabric_fingerprint(), fp0);
        assert_eq!(session.mesh().free_replicas(), 8);
        let r2 = session.step(&batch);
        assert!(r2.iteration.iter_time_s > 0.0);
    }

    #[test]
    fn mesh_event_between_identical_batches_forces_a_resolve() {
        // ISSUE-9 acceptance: the pipeline's ordered SyncMesh message
        // must invalidate the scheduling thread's exact-hit schedule
        // cache — serving a stale cached placement onto a now-occupied
        // rank would be a correctness bug, not a perf bug.
        let mut session = dhp_session(8);
        let mut sampler = sampler(DatasetKind::OpenVid, 0x5CA1E);
        let batch = sampler.sample_batch(24);

        let r0 = session.step(&batch);
        assert!(r0.failed.is_none());
        // Identical batch, unchanged mesh: the steady state the cache
        // exists for — every micro-batch is an exact hit.
        let r1 = session.step(&batch);
        assert!(r1.failed.is_none());
        assert!(
            r1.solve_cache_hits > 0,
            "identical re-submitted batch never hit the schedule cache"
        );
        assert_eq!(
            r1.solve_fast_paths, 0,
            "ε fast path must be off by default"
        );

        // Occupy between two identical batches: the SyncMesh control
        // message must clear the cache, so the same batch re-solves
        // against the shrunken mesh and never lands on occupied ranks.
        let occupied = vec![0usize, 5];
        session
            .apply(&[MeshEvent::Occupy(occupied.clone())])
            .unwrap();
        let r2 = session.step(&batch);
        assert!(r2.failed.is_none());
        assert_eq!(
            r2.solve_cache_hits, 0,
            "a mesh event must invalidate the schedule cache"
        );
        for schedule in &r2.schedules {
            for wave in &schedule.waves {
                for g in &wave.groups {
                    for &r in &g.ranks {
                        assert!(
                            !occupied.contains(&r),
                            "stale cached placement: rank {r} is occupied"
                        );
                    }
                }
            }
        }
        // Telemetry stays coherent through the façade.
        assert!((0.0..=1.0).contains(&r2.solve_pruned_frac));
    }

    #[test]
    fn session_is_deterministic_under_a_mesh_event_trace() {
        // Same seed + same MeshEvent trace ⇒ bit-identical StepReport
        // digests (wall-clock fields excluded by construction).
        let run = || -> Vec<u64> {
            let mut session = dhp_session(8);
            let mut sampler = sampler(DatasetKind::OpenVid, 0xD15);
            let mut digests = Vec::new();
            for step in 0..6u64 {
                if step == 2 {
                    session
                        .apply(&[MeshEvent::Occupy(vec![0, 2])])
                        .unwrap();
                }
                if step == 4 {
                    session.apply(&[MeshEvent::Release(vec![0])]).unwrap();
                }
                let batch = sampler.sample_batch(16);
                digests.push(session.step(&batch).digest());
            }
            digests
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "session must replay bit-identically");
        // Sanity: the trace actually perturbed the run (the occupy step
        // differs from the first step's digest universe).
        assert_eq!(a.len(), 6);
    }

    #[test]
    fn prefetched_steps_execute_in_submission_order() {
        let mut session = dhp_session(8);
        let mut sampler = sampler(DatasetKind::InternVid, 0xF1F0);
        let batches: Vec<Vec<_>> =
            [8usize, 16, 24, 12].iter().map(|&n| sampler.sample_batch(n)).collect();

        session.prefetch(&batches[0]);
        session.prefetch(&batches[1]);
        let r0 = session.step_prefetched(0.0).unwrap();
        assert_eq!(r0.step, 0);
        session.prefetch(&batches[2]);
        let r1 = session.step_prefetched(0.0).unwrap();
        let r2 = session.step_prefetched(0.0).unwrap();
        assert_eq!((r1.step, r2.step), (1, 2));
        assert!(session.step_prefetched(0.0).is_none(), "queue drained");

        // apply() between steps only: a pending prefetch rejects events…
        session.prefetch(&batches[3]);
        assert!(session.apply(&[MeshEvent::Occupy(vec![0])]).is_err());
        let r3 = session.step_prefetched(0.0).unwrap();
        assert_eq!(r3.step, 3);
        // …and drains cleanly afterwards.
        session.apply(&[MeshEvent::Occupy(vec![0])]).unwrap();
        assert_eq!(session.mesh().free_replicas(), 7);
    }

    #[test]
    fn apply_validates_event_traces_atomically() {
        let mut session = dhp_session(8);
        // Out-of-range rank.
        assert!(session.apply(&[MeshEvent::Occupy(vec![99])]).is_err());
        // Releasing a free rank.
        assert!(session.apply(&[MeshEvent::Release(vec![0])]).is_err());
        // Double-occupy within one trace.
        assert!(session.apply(&[MeshEvent::Occupy(vec![1, 1])]).is_err());
        // A trace that occupies everything leaves nothing to schedule.
        assert!(session
            .apply(&[MeshEvent::Occupy((0..8).collect())])
            .is_err());
        // Every rejected trace left the session untouched.
        assert_eq!(session.mesh().free_replicas(), 8);
        // A valid composite trace commits atomically.
        session
            .apply(&[
                MeshEvent::Occupy(vec![0, 1]),
                MeshEvent::Release(vec![0]),
            ])
            .unwrap();
        assert_eq!(session.mesh().free_replicas(), 7);
        assert!(!session.mesh().is_rank_free(1));
    }

    #[test]
    fn warm_start_controls_first_step_creation_charge() {
        let preset = by_name("InternVL3-8B").unwrap();
        let mut cluster = ClusterConfig::default().with_npus(32);
        cluster.tp = 2;
        cluster.pp = 2;
        let hw = HardwareSpec {
            peak_flops: 376e12 * 4.0,
            ..HardwareSpec::default()
        };
        let cost = CostModel {
            coeffs: CostCoeffs::analytic(&preset, TrainStage::Full, &hw),
            memory: MemoryModel {
                e_bytes: 8192.0 * preset.act_bytes_per_token() + 2e9,
                m_states: 2e9,
                m_token: preset.act_bytes_per_token(),
            },
        };
        let build = |warm: bool| {
            let scheduler = Scheduler::new(
                cost.clone(),
                crate::parallel::DeviceMesh::new(&cluster),
            );
            let sim = ClusterSim::new(preset.clone(), TrainStage::Full, cluster.clone());
            DhpSession::builder(Box::new(scheduler), sim)
                .warm_start(warm)
                .build()
        };
        let batch = sampler(DatasetKind::Msrvtt, 7).sample_batch(16);

        let mut warm = build(true);
        let r = warm.step(&batch);
        assert_eq!(
            r.iteration.reconfig_serial_s, 0.0,
            "warm start pays creation before the measured stream"
        );

        let mut cold = build(false);
        let r0 = cold.step(&batch);
        assert!(
            r0.iteration.reconfig_serial_s > 0.0,
            "a cold session's first step must create its groups"
        );
        // Identical second batch: everything hits the pool, and the
        // previous step's compute hides any residual creation.
        let r1 = cold.step(&batch);
        assert_eq!(r1.iteration.reconfig_serial_s, 0.0);
        assert_eq!(r1.iteration.reconfig_time_s, 0.0);
        assert!(r1.replay_rate > 0.99, "stationary batch must replay");
    }

    #[test]
    fn rank_failure_shrinks_resolves_and_charges_recovery() {
        let script = vec![
            vec![],
            vec![FaultEvent::RankFailure { rank: 2 }],
            vec![],
            vec![FaultEvent::Recovery { ranks: vec![2] }],
        ];
        let mut session = dhp_builder(8)
            .fault_injector(FaultInjector::scripted(8, script))
            .build();
        let mut sampler = sampler(DatasetKind::Msrvtt, 0xFA11);
        let batch = sampler.sample_batch(16);

        let r0 = session.step(&batch);
        assert!(r0.failed.is_none());
        assert!(r0.faults.is_empty());
        assert_eq!(r0.recovery_time_s, 0.0);
        assert_eq!(session.mesh().free_replicas(), 8);

        // The failure lands BEFORE step 1's solve: DHP re-solves on the
        // 7 survivors and completes the step.
        let r1 = session.step(&batch);
        assert_eq!(r1.faults, vec![FaultEvent::RankFailure { rank: 2 }]);
        assert!(r1.failed.is_none(), "DHP must re-solve on survivors");
        assert!(r1.iteration.iter_time_s > 0.0);
        assert_eq!(session.mesh().free_replicas(), 7);
        assert_eq!(session.downed_ranks(), vec![2]);
        for s in &r1.schedules {
            for w in &s.waves {
                for g in &w.groups {
                    assert!(!g.ranks.contains(&2), "dead rank placed");
                }
            }
        }
        // Recovery is charged honestly: at least the checkpoint restore,
        // plus the step-0 work lost since the (nonexistent) checkpoint.
        let restore = CheckpointCostModel::for_params(8.0).restore_time_s();
        assert!(
            r1.recovery_time_s >= restore + r0.iteration.iter_time_s,
            "recovery {} must cover restore {} + lost work {}",
            r1.recovery_time_s,
            restore,
            r0.iteration.iter_time_s
        );
        assert!(r1.total_time_s() > r1.iteration.iter_time_s);

        let r2 = session.step(&batch);
        assert!(r2.failed.is_none());
        assert_eq!(r2.recovery_time_s, 0.0);

        // Repair completes: the rank is re-admitted and capacity returns.
        let r3 = session.step(&batch);
        assert_eq!(r3.faults, vec![FaultEvent::Recovery { ranks: vec![2] }]);
        assert_eq!(session.mesh().free_replicas(), 8);
        assert!(session.downed_ranks().is_empty());
    }

    #[test]
    fn quiet_injector_is_bit_identical_to_no_injector() {
        use crate::cluster::FaultConfig;
        let run = |with_injector: bool| -> Vec<u64> {
            let mut builder = dhp_builder(8);
            if with_injector {
                builder = builder
                    .fault_injector(FaultInjector::new(8, FaultConfig::quiet(7)));
            }
            let mut session = builder.build();
            let mut sampler = sampler(DatasetKind::OpenVid, 0x2E20);
            (0..4)
                .map(|_| session.step(&sampler.sample_batch(12)).digest())
                .collect()
        };
        assert_eq!(
            run(true),
            run(false),
            "a quiet injector must not drift from the fault-free path"
        );
    }

    #[test]
    fn chronic_straggler_is_fenced_at_threshold() {
        let straggle = |rank| {
            vec![FaultEvent::Straggler {
                rank,
                slowdown: 3.0,
            }]
        };
        let mut session = dhp_builder(8)
            .fault_injector(FaultInjector::scripted(8, vec![
                straggle(1),
                straggle(1),
                straggle(1),
            ]))
            .straggler_fence_threshold(3)
            .build();
        let mut sampler = sampler(DatasetKind::InternVid, 0x57A6);
        let batch = sampler.sample_batch(16);

        let r0 = session.step(&batch);
        assert_eq!(r0.faults.len(), 1);
        assert!(session.fenced_ranks().is_empty());
        // If the slowed rank was placed, its waves must show inflation.
        let touches_rank_1 = r0
            .schedules
            .iter()
            .flat_map(|s| &s.waves)
            .flat_map(|w| &w.groups)
            .any(|g| g.ranks.contains(&1));
        if touches_rank_1 {
            assert!(r0.iteration.straggle_s > 0.0);
        }

        let _ = session.step(&batch);
        assert!(session.fenced_ranks().is_empty(), "below threshold");

        // Third strike: the rank is fenced BEFORE the solve, so this
        // step's schedule already avoids it and nothing is slowed.
        let r2 = session.step(&batch);
        assert_eq!(session.fenced_ranks(), vec![1]);
        assert_eq!(session.mesh().free_replicas(), 7);
        assert_eq!(r2.iteration.straggle_s, 0.0);
        for s in &r2.schedules {
            for w in &s.waves {
                for g in &w.groups {
                    assert!(!g.ranks.contains(&1), "fenced rank placed");
                }
            }
        }
    }

    #[test]
    fn static_baseline_reports_typed_failed_steps_and_recovers() {
        let script = vec![
            vec![FaultEvent::RankFailure { rank: 0 }],
            vec![],
            vec![FaultEvent::Recovery { ranks: vec![0] }],
        ];
        let mut session = megatron_builder(8)
            .fault_injector(FaultInjector::scripted(8, script))
            .build();
        let mut sampler = sampler(DatasetKind::Msrvtt, 0x3E66);
        let batch = sampler.sample_batch(16);

        // The static grid cannot fit 7 replicas: a typed failed step,
        // not a panic — and the recovery charge is still accounted.
        let r0 = session.step(&batch);
        match &r0.failed {
            Some(ScheduleError::MeshShrunk { need, free, .. }) => {
                assert_eq!((*need, *free), (8, 7));
            }
            other => panic!("expected MeshShrunk, got {other:?}"),
        }
        assert!(r0.schedules.is_empty());
        assert_eq!(r0.iteration.iter_time_s, 0.0);
        assert!(r0.recovery_time_s > 0.0, "the failure itself still bills");

        // Still shrunk: still failing, still not panicking.
        let r1 = session.step(&batch);
        assert!(r1.failed.is_some());

        // Repair restores full strength: the baseline retries and runs.
        let r2 = session.step(&batch);
        assert!(r2.failed.is_none(), "full-strength retry must succeed");
        assert!(r2.iteration.iter_time_s > 0.0);
    }

    #[test]
    fn checkpoint_cadence_charges_saves() {
        let mut session = dhp_builder(8).checkpoint_interval(2).build();
        let mut sampler = sampler(DatasetKind::Msrvtt, 0xC4D);
        let batch = sampler.sample_batch(12);
        let save = CheckpointCostModel::for_params(8.0).save_time_s();

        let r0 = session.step(&batch);
        assert_eq!(r0.checkpoint_time_s, 0.0);
        let r1 = session.step(&batch);
        assert!((r1.checkpoint_time_s - save).abs() < 1e-12);
        assert!(r1.total_time_s() > r1.iteration.iter_time_s);
        let r2 = session.step(&batch);
        assert_eq!(r2.checkpoint_time_s, 0.0);
    }

    #[test]
    fn subscription_source_matches_hand_pushed_events_property() {
        // Property (random co-tenant occupancy traces): a session fed
        // occupancy through the async MeshEventSource subscription is
        // digest-identical, step for step, to a twin with the same
        // events hand-pushed into apply(). Exercises is_idle() at every
        // apply point and the co-tenant coherence of the simulator's
        // idle-fraction / fabric-capacity answers along the way.
        use crate::cluster_service::{channel_source, MeshEventSource};
        use crate::util::rng::Rng;

        for seed in 0..6u64 {
            let mut rng = Rng::new(0xC07E ^ seed);
            let replicas = 8;
            let mut sub = dhp_builder(replicas).build();
            let mut hand = dhp_builder(replicas).build();
            let (feed, mut source) = channel_source();
            // MSRVTT: the longest sample fits a degree-2 group, so even
            // a 2-rank residual mesh can always place the batch.
            let mut sampler_a = sampler(DatasetKind::Msrvtt, 0x90 + seed);
            let mut sampler_b = sampler(DatasetKind::Msrvtt, 0x90 + seed);
            // Co-tenant occupancy state, mutated by a random trace.
            let mut held: Vec<RankId> = Vec::new();
            for step in 0..5u64 {
                let mut events = Vec::new();
                if step > 0 {
                    // Release everything the co-tenant held, then claim
                    // a fresh random subset (never the whole mesh).
                    if !held.is_empty() {
                        events.push(MeshEvent::Release(held.clone()));
                        held.clear();
                    }
                    for r in 0..replicas {
                        if held.len() + 1 < replicas && rng.bool(0.4) {
                            held.push(r);
                        }
                    }
                    if !held.is_empty() {
                        events.push(MeshEvent::Occupy(held.clone()));
                    }
                }
                for ev in &events {
                    feed.push(7, ev.clone());
                }
                let polled = source.poll(7);
                assert_eq!(polled, events, "subscription must preserve order");
                if !polled.is_empty() {
                    assert!(sub.is_idle() && hand.is_idle());
                    sub.apply(&polled).unwrap();
                    hand.apply(&events).unwrap();
                }
                let batch_a = sampler_a.sample_batch(12);
                let batch_b = sampler_b.sample_batch(12);
                let ra = sub.step(&batch_a);
                let rb = hand.step(&batch_b);
                assert!(ra.failed.is_none() && rb.failed.is_none());
                assert_eq!(
                    ra.digest(),
                    rb.digest(),
                    "seed {seed} step {step}: subscription-fed digest drifted"
                );
                assert_eq!(sub.pending_steps(), 0);
            }
        }
    }

    #[test]
    fn n_sessions_interleave_on_one_shared_mesh() {
        // Satellite regression: three sessions share one physical
        // 8-replica cluster, each seeing the others' grants as
        // occupancy. Disjoint grants ⇒ every session steps cleanly, and
        // each is bit-identical to a solo session with the same static
        // occupancy — interleaving order cannot leak state across
        // sessions.
        let grants: [&[RankId]; 3] = [&[0, 1], &[2, 3, 4], &[5, 6, 7]];
        let mut sessions: Vec<DhpSession> = Vec::new();
        for grant in grants {
            let mut s = dhp_builder(8).build();
            let complement: Vec<RankId> =
                (0..8).filter(|r| !grant.contains(r)).collect();
            assert!(s.is_idle());
            s.apply(&[MeshEvent::Occupy(complement)]).unwrap();
            sessions.push(s);
        }
        let mut digests = vec![0u64; 3];
        for step in 0..3u64 {
            for (i, s) in sessions.iter_mut().enumerate() {
                let mut smp = sampler(DatasetKind::Msrvtt, 0x515E + i as u64);
                // Re-derive this step's batch deterministically.
                let mut batch = Vec::new();
                for _ in 0..=step {
                    batch = smp.sample_batch(8);
                }
                let r = s.step(&batch);
                assert!(r.failed.is_none(), "session {i} step {step} failed");
                digests[i] = digests[i].rotate_left(1) ^ r.digest();
            }
        }
        // Solo replays: same occupancy, same batches, no interleaving.
        for (i, grant) in grants.iter().enumerate() {
            let mut solo = dhp_builder(8).build();
            let complement: Vec<RankId> =
                (0..8).filter(|r| !grant.contains(r)).collect();
            solo.apply(&[MeshEvent::Occupy(complement)]).unwrap();
            let mut smp = sampler(DatasetKind::Msrvtt, 0x515E + i as u64);
            let mut digest = 0u64;
            for _ in 0..3 {
                let r = solo.step(&smp.sample_batch(8));
                digest = digest.rotate_left(1) ^ r.digest();
            }
            assert_eq!(
                digest, digests[i],
                "session {i}: interleaved run drifted from solo replay"
            );
        }
    }
}
