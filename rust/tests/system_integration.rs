//! Whole-system integration: scheduler + baselines + simulator + async
//! pipeline + CLI dispatch composing, at realistic experiment scales.

use dhp::baselines::SchedulePolicy;
use dhp::cluster::CommKind;
use dhp::config::presets::{by_name, PRESETS};
use dhp::config::{TrainConfig, TrainStage};
use dhp::data::batch::GlobalBatch;
use dhp::data::datasets::DatasetKind;
use dhp::experiments::harness::{dispatch, run_policy, ExpContext, PolicySet};
use dhp::scheduler::pipeline::SchedulePipeline;
use dhp::util::cli::Args;
use dhp::util::quickcheck::forall;

fn ctx(npus: usize, dataset: DatasetKind) -> ExpContext {
    ExpContext::new(
        by_name("InternVL3-8B").unwrap(),
        dataset,
        npus,
        TrainStage::Full,
    )
}

#[test]
fn full_iteration_all_policies_consistent() {
    let ctx = ctx(32, DatasetKind::OpenVid).with_gbs(96).with_steps(0, 2);
    let set = PolicySet::build(&ctx);
    let results = [
        run_policy(&ctx, &set.megatron),
        run_policy(&ctx, &set.deepspeed),
        run_policy(&ctx, &set.dhp),
    ];
    for r in &results {
        assert!(r.mean_iter_s.is_finite() && r.mean_iter_s > 0.0);
        assert!(r.tokens_per_s > 0.0);
        assert!(r.mean_solver_s <= r.mean_schedule_s + 1e-9);
        assert!((0.0..=1.0).contains(&r.mean_idle_fraction));
    }
    // DHP does not lose to either baseline.
    assert!(results[2].mean_iter_s <= results[0].mean_iter_s * 1.01);
    assert!(results[2].mean_iter_s <= results[1].mean_iter_s * 1.01);
}

#[test]
fn headline_claim_small_scale() {
    // The paper's headline: DHP beats the BEST tuned baseline, more on
    // skewed data. Checked at reduced scale for test runtime.
    let skewed = ctx(32, DatasetKind::OpenVid).with_gbs(128).with_steps(1, 3);
    let set = PolicySet::build(&skewed);
    let dhp = run_policy(&skewed, &set.dhp);
    let mega = run_policy(&skewed, &set.megatron);
    let ds = run_policy(&skewed, &set.deepspeed);
    let best = mega.mean_iter_s.min(ds.mean_iter_s);
    assert!(
        dhp.mean_iter_s < best,
        "DHP {} should beat best baseline {best}",
        dhp.mean_iter_s
    );
}

#[test]
fn async_pipeline_with_simulated_training_loop() {
    let ctx = ctx(32, DatasetKind::InternVid);
    let pipe = SchedulePipeline::spawn(ctx.dhp(), 2);
    let sim = ctx.sim();
    let mut sampler = ctx.sampler();
    let batches: Vec<Vec<_>> = (0..4).map(|_| sampler.sample_batch(24)).collect();
    pipe.submit(0, batches[0].clone());
    let mut total_sim = 0.0;
    for step in 0..4u64 {
        if (step as usize) + 1 < batches.len() {
            pipe.submit(step + 1, batches[step as usize + 1].clone());
        }
        let done = pipe.recv().unwrap();
        assert_eq!(done.step, step);
        let seqs = &batches[step as usize];
        let schedule = done.schedule.unwrap();
        schedule.validate(seqs, ctx.replicas()).unwrap();
        total_sim += sim
            .execute_schedule(seqs, &schedule, CommKind::RingCp)
            .iter()
            .map(|w| w.makespan_s)
            .sum::<f64>();
    }
    pipe.shutdown();
    assert!(total_sim > 0.0);
}

#[test]
fn dispatch_lists_cover_plans_for_all_policies() {
    let ctx = ctx(32, DatasetKind::Msrvtt).with_gbs(48);
    let set = PolicySet::build(&ctx);
    let mut sampler = ctx.sampler();
    let batch = GlobalBatch {
        step: 0,
        sequences: sampler.sample_batch(48),
    };
    let mbs = ctx.micro_batch_planner().plan(&batch);
    let policies: [&dyn SchedulePolicy; 3] =
        [&set.megatron, &set.deepspeed, &set.dhp];
    for policy in policies {
        for mb in &mbs {
            let schedule = policy.schedule(&mb.sequences).unwrap();
            for plan in &schedule.waves {
                let entries = dispatch(&mb.sequences, plan);
                // Every assigned sequence's tokens are fully covered.
                for g in &plan.groups {
                    for &si in &g.seq_idxs {
                        let covered: u64 = entries
                            .iter()
                            .filter(|e| e.seq_idx == si)
                            .map(|e| e.token_end - e.token_start)
                            .sum();
                        assert_eq!(covered, mb.sequences[si].len());
                    }
                }
            }
        }
    }
}

#[test]
fn cli_dispatch_smoke() {
    // Cheap CLI paths: help / models / schedule / fig1 / fig2 / tab4.
    for tokens in [
        vec!["help"],
        vec!["models"],
        vec!["schedule", "--gbs", "12", "--npus", "16"],
        vec!["reproduce", "fig1", "--samples", "2000"],
        vec!["reproduce", "fig2", "--batch", "12", "--npus", "16"],
        vec!["reproduce", "tab4", "--gbs", "24", "--npus", "16"],
    ] {
        let args = Args::parse(tokens.iter().map(|s| s.to_string())).unwrap();
        dhp::report::run_cli(args).unwrap_or_else(|e| panic!("{tokens:?}: {e}"));
    }
    // Unknown command errors cleanly.
    let bad = Args::parse(["nope".to_string()]).unwrap();
    assert!(dhp::report::run_cli(bad).is_err());
}

#[test]
fn config_file_round_trip_drives_context() {
    let cfg = TrainConfig::from_toml(
        "[train]\ngbs = 64\nmodel = \"Qwen3VL-4B\"\ndataset = \"internvid\"\n\
         pool_cap_groups = 6\n\
         [cluster]\nnodes = 4\nnpus_per_node = 8\ntp = 2\npp = 2\n",
    )
    .unwrap();
    assert_eq!(cfg.cluster.replicas(), 8);
    assert_eq!(cfg.model.name, "Qwen3VL-4B");
    assert_eq!(cfg.gbs, 64);
    // The parsed config drives a real context — including the session's
    // pool budget, so the TOML knob is live end to end.
    let ctx = ExpContext::from_train_config(&cfg);
    assert_eq!(ctx.replicas(), 8);
    assert_eq!(ctx.gbs, 64);
    assert_eq!(
        ctx.pool_capacity,
        dhp::parallel::PoolCapacity::MaxGroups(6)
    );
    // The budget reaches the session's actual pool.
    let mut session = ctx.session();
    let mut sampler = ctx.sampler();
    let report = session.step(&sampler.sample_batch(12));
    assert!(report.iteration.iter_time_s > 0.0);
    let stats = session.pool_stats();
    assert!(stats.hits + stats.misses > 0, "capped session pool saw traffic");
}

#[test]
fn property_every_policy_schedules_any_workload() {
    forall(10, 0x515, |rng| {
        let npus = *rng.choose(&[16usize, 32]);
        let kind = *rng.choose(&DatasetKind::all());
        let mut c = ctx(npus, kind);
        c.seed = rng.next_u64();
        let set = PolicySet::build(&c);
        let mut sampler = c.sampler();
        let n = rng.range_usize(1, 48);
        let seqs = sampler.sample_batch(n);
        let policies: [&dyn SchedulePolicy; 3] =
            [&set.megatron, &set.deepspeed, &set.dhp];
        for policy in policies {
            let schedule = policy
                .schedule(&seqs)
                .map_err(|e| format!("{} refused a full mesh: {e}", policy.name()))?;
            schedule
                .validate(&seqs, c.replicas())
                .map_err(|e| format!("{} on {n} seqs: {e}", policy.name()))?;
        }
        Ok(())
    });
}

#[test]
fn all_presets_work_end_to_end() {
    for preset in PRESETS.iter() {
        let mut c = ExpContext::new(
            preset.clone(),
            DatasetKind::OpenVid,
            16,
            TrainStage::FrozenVision,
        )
        .with_gbs(24)
        .with_steps(0, 1);
        c.seed = 5;
        let set = PolicySet::build(&c);
        let r = run_policy(&c, &set.dhp);
        assert!(
            r.mean_iter_s.is_finite() && r.mean_iter_s > 0.0,
            "{}: {r:?}",
            preset.name
        );
    }
}
