//! Integration tests over the REAL PJRT runtime: load the AOT artifacts
//! produced by `make artifacts`, execute them, and verify numerics +
//! training behaviour end to end. Skipped gracefully when artifacts are
//! missing (CI without `make artifacts`).

use std::path::{Path, PathBuf};

use dhp::data::corpus::CorpusGenerator;
use dhp::runtime::{load_params, ArtifactKind, Manifest, Runtime};
use dhp::train::{Adam, AdamConfig};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_lists_canonical_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    for name in [
        "model.hlo.txt",
        "tiny.hlo.txt",
        "tiny_params.f32",
        "e2e_grad.hlo.txt",
    ] {
        assert!(m.get(name).is_some(), "manifest missing {name}");
    }
    assert!(m.sweep("prof_fwd_").len() >= 3);
    let tiny = m.get("model.hlo.txt").unwrap();
    assert_eq!(tiny.kind, ArtifactKind::GradStep);
    assert_eq!(tiny.param_count, 146_752);
}

#[test]
fn params_blob_matches_manifest() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let meta = m.get("tiny_params.f32").unwrap();
    let params = load_params(&dir.join("tiny_params.f32")).unwrap();
    assert_eq!(params.len(), meta.param_count);
    // Sane initialization: finite, non-degenerate.
    assert!(params.iter().all(|p| p.is_finite()));
    let nonzero = params.iter().filter(|p| **p != 0.0).count();
    assert!(nonzero > params.len() / 2);
}

#[test]
fn pjrt_grad_step_trains_tiny_model() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = rt.load(&dir, "model.hlo.txt").unwrap();
    let meta = model.meta.clone();
    let mut params = load_params(&dir.join("tiny_params.f32")).unwrap();
    let mut corpus = CorpusGenerator::new(meta.vocab, meta.patch_dim, 42);
    let mut opt = Adam::new(
        params.len(),
        AdamConfig {
            lr: 5e-3,
            ..Default::default()
        },
    );

    // Fixed batch: the model must fit it (memorization ⇒ loss drops fast).
    let (vis, tok, tgt) =
        corpus.sample_flat_batch(meta.batch, meta.seq_vision, meta.seq_text);
    let first = model.grad_step(&params, &vis, &tok, &tgt).unwrap();
    assert!(first.loss.is_finite());
    // Near-uniform init: loss ≈ ln(vocab).
    let uniform = (meta.vocab as f32).ln();
    assert!((first.loss - uniform).abs() < 1.5, "loss {}", first.loss);
    assert_eq!(first.grads.len(), params.len());

    let mut last = first.loss;
    for _ in 0..30 {
        let out = model.grad_step(&params, &vis, &tok, &tgt).unwrap();
        opt.step(&mut params, &out.grads);
        last = out.loss;
    }
    assert!(
        last < first.loss - 0.5,
        "loss did not drop on fixed batch: {} -> {last}",
        first.loss
    );
}

#[test]
fn pjrt_fwd_loss_matches_grad_step_loss() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let grad = rt.load(&dir, "model.hlo.txt").unwrap();
    let fwd = rt.load(&dir, "tiny.hlo.txt").unwrap();
    let params = load_params(&dir.join("tiny_params.f32")).unwrap();
    let meta = grad.meta.clone();
    let mut corpus = CorpusGenerator::new(meta.vocab, meta.patch_dim, 7);
    let (vis, tok, tgt) =
        corpus.sample_flat_batch(meta.batch, meta.seq_vision, meta.seq_text);
    let g = grad.grad_step(&params, &vis, &tok, &tgt).unwrap();
    let f = fwd.fwd_loss(&params, &vis, &tok, &tgt).unwrap();
    // Same params, same inputs, same graph → identical losses.
    assert!((g.loss - f).abs() < 1e-5, "grad {} vs fwd {f}", g.loss);
}

#[test]
fn pjrt_execution_is_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = rt.load(&dir, "tiny.hlo.txt").unwrap();
    let params = load_params(&dir.join("tiny_params.f32")).unwrap();
    let meta = model.meta.clone();
    let mut corpus = CorpusGenerator::new(meta.vocab, meta.patch_dim, 9);
    let (vis, tok, tgt) =
        corpus.sample_flat_batch(meta.batch, meta.seq_vision, meta.seq_text);
    let a = model.fwd_loss(&params, &vis, &tok, &tgt).unwrap();
    let b = model.fwd_loss(&params, &vis, &tok, &tgt).unwrap();
    assert_eq!(a, b);
}

#[test]
fn wrong_shapes_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = rt.load(&dir, "model.hlo.txt").unwrap();
    let params = load_params(&dir.join("tiny_params.f32")).unwrap();
    assert!(model.grad_step(&params[..10], &[], &[], &[]).is_err());
    let meta = model.meta.clone();
    let vis = vec![0.0f32; meta.batch * meta.seq_vision * meta.patch_dim];
    let tok = vec![0i32; 3]; // wrong
    let tgt = vec![0i32; meta.batch * meta.seq_text];
    assert!(model.grad_step(&params, &vis, &tok, &tgt).is_err());
}

#[test]
fn profiler_fits_real_runtime_structurally() {
    // Wall-clock profiling under `cargo test`'s parallel threads on a
    // single-core box is too noisy for a tight MAPE assertion (the tab3
    // bench, run serially, reports < 2% — paper band < 8%). Here we
    // assert the structural properties that must hold regardless of
    // contention: a valid non-negative fit over all buckets whose
    // predictions grow with sequence length.
    let Some(dir) = artifacts_dir() else { return };
    let (coeffs, fit) =
        dhp::experiments::estimator::fit_from_runtime(&dir, 3).unwrap();
    assert!(coeffs.alpha1 >= 0.0 && coeffs.alpha2 >= 0.0 && coeffs.beta1 >= 0.0);
    assert!(fit.n >= 3);
    let predict = |l: f64| coeffs.alpha1 * l * l + coeffs.alpha2 * l + coeffs.beta1;
    assert!(predict(768.0) > predict(128.0));
    assert!(predict(128.0) > 0.0);
}
