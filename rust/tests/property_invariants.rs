//! Property-based tests over coordinator invariants (routing, batching,
//! parallel state) — the whole-system complement to the per-module
//! quickcheck suites.

use dhp::config::presets::{by_name, PRESETS};
use dhp::config::{ClusterConfig, TrainStage};
use dhp::cost::{CostCoeffs, CostModel, HardwareSpec, MemoryModel, WorkloadAgg};
use dhp::data::batch::{GlobalBatch, MicroBatchPlanner};
use dhp::data::datasets::{DatasetKind, DatasetSampler, TokenizerSpec};
use dhp::data::sequence::Sequence;
use dhp::parallel::{DeviceMesh, GroupKind, GroupPool, ParallelState};
use dhp::util::quickcheck::forall;
use dhp::util::rng::Rng;

fn rand_cluster(rng: &mut Rng) -> ClusterConfig {
    let mut c = ClusterConfig::default().with_npus(*rng.choose(&[8, 16, 32, 64]));
    c.tp = *rng.choose(&[1, 2]);
    c.pp = *rng.choose(&[1, 2]);
    c
}

#[test]
fn mesh_allocation_always_disjoint_and_local() {
    forall(200, 0xA110, |rng| {
        let cluster = rand_cluster(rng);
        let mesh = DeviceMesh::new(&cluster);
        let n = mesh.replicas;
        // Random degree vector within budget.
        let mut degrees = Vec::new();
        let mut left = n;
        while left > 0 && rng.bool(0.85) {
            let d = rng.range_usize(1, left + 1);
            degrees.push(d);
            left -= d;
        }
        if degrees.is_empty() {
            return Ok(());
        }
        let placements = mesh.allocate(&degrees);
        // Disjoint + arity.
        let mut seen = std::collections::HashSet::new();
        for (d, ranks) in degrees.iter().zip(&placements) {
            if ranks.len() != *d {
                return Err(format!("arity {} != {d}", ranks.len()));
            }
            for &r in ranks {
                if r >= n || !seen.insert(r) {
                    return Err(format!("rank {r} reused/out of range"));
                }
            }
        }
        // Locality guarantee: the LARGEST group is placed first into an
        // empty mesh, so if it fits within one node it must be intra-node.
        // (Smaller later groups may legitimately fragment across nodes.)
        let (imax, dmax) = degrees
            .iter()
            .enumerate()
            .max_by_key(|(_, d)| **d)
            .map(|(i, d)| (i, *d))
            .unwrap();
        if dmax <= mesh.replicas_per_node && !mesh.is_intra_node(&placements[imax]) {
            return Err(format!(
                "largest group (degree {dmax}) spans nodes: {:?} (rpn {})",
                placements[imax], mesh.replicas_per_node
            ));
        }
        Ok(())
    });
}

#[test]
fn mesh_allocation_is_deterministic() {
    // Placement determinism is what makes the group pool effective: the
    // same degree vector must always land on the same rank blocks.
    forall(100, 0xA118, |rng| {
        let cluster = rand_cluster(rng);
        let mesh = DeviceMesh::new(&cluster);
        let n = mesh.replicas;
        let mut degrees = Vec::new();
        let mut left = n;
        while left > 0 && rng.bool(0.8) {
            let d = rng.range_usize(1, left + 1);
            degrees.push(d);
            left -= d;
        }
        if degrees.is_empty() {
            return Ok(());
        }
        let a = mesh.allocate(&degrees);
        let b = mesh.allocate(&degrees);
        if a != b {
            return Err(format!("allocate({degrees:?}) diverged: {a:?} vs {b:?}"));
        }
        // Blocks come out sorted (the pool's canonical identity).
        for ranks in &a {
            if ranks.windows(2).any(|w| w[0] >= w[1]) {
                return Err(format!("unsorted block {ranks:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn placed_schedules_have_disjoint_in_budget_rank_sets() {
    // The placed-plan invariant, end to end: every wave of every DHP
    // schedule binds each group to exactly `degree` in-range ranks, with
    // no rank appearing twice within a wave and Σ degrees ≤ N.
    use dhp::experiments::harness::ExpContext;
    forall(15, 0xA119, |rng| {
        let npus = *rng.choose(&[16usize, 32, 64]);
        let kind = *rng.choose(&DatasetKind::all());
        let mut ctx = ExpContext::new(
            by_name("InternVL3-8B").unwrap(),
            kind,
            npus,
            TrainStage::Full,
        );
        ctx.seed = rng.next_u64();
        let sch = ctx.dhp();
        let mut sampler = ctx.sampler();
        let seqs = sampler.sample_batch(rng.range_usize(1, 64));
        let schedule = sch.schedule(&seqs);
        let n = ctx.replicas();
        for (wi, wave) in schedule.waves.iter().enumerate() {
            wave.validate_placement(n)
                .map_err(|e| format!("wave {wi}: {e}"))?;
            let mut seen = std::collections::HashSet::new();
            let mut total = 0usize;
            for g in &wave.groups {
                if g.ranks.len() != g.degree {
                    return Err(format!(
                        "wave {wi}: arity {} != degree {}",
                        g.ranks.len(),
                        g.degree
                    ));
                }
                total += g.degree;
                for &r in &g.ranks {
                    if r >= n || !seen.insert(r) {
                        return Err(format!("wave {wi}: rank {r} reused/out of range"));
                    }
                }
                // The recorded ring bandwidth matches the actual set.
                let bw = sch.mesh.ring_bandwidth(&g.ranks);
                if g.ring_bw != bw {
                    return Err(format!("wave {wi}: ring_bw {} != {}", g.ring_bw, bw));
                }
            }
            if total > n {
                return Err(format!("wave {wi}: {total} ranks > N = {n}"));
            }
        }
        Ok(())
    });
}

#[test]
fn fragmented_mesh_schedules_avoid_occupied_ranks() {
    // Fabric-aware scheduling end to end on randomly fragmented meshes:
    // whatever fraction of the mesh concurrent jobs hold, every schedule
    // stays valid, never touches an occupied rank, and never plans more
    // ranks than are actually free.
    forall(15, 0xF4A8, |rng| {
        let cluster = rand_cluster(rng);
        let mut mesh = DeviceMesh::new(&cluster);
        let n = mesh.replicas;
        // Occupy a random subset (up to ~60%), leaving at least 2 free.
        let mut occupied = Vec::new();
        for r in 0..n {
            if occupied.len() + 2 < n && rng.bool(0.4) {
                occupied.push(r);
            }
        }
        mesh.occupy(&occupied);
        let free = mesh.free_replicas();
        let preset = by_name("InternVL3-8B").unwrap();
        let hw = HardwareSpec {
            peak_flops: 376e12 * (cluster.tp * cluster.pp) as f64,
            ..HardwareSpec::default()
        };
        let cost = CostModel {
            coeffs: CostCoeffs::analytic(&preset, TrainStage::Full, &hw),
            memory: MemoryModel {
                e_bytes: 8192.0 * preset.act_bytes_per_token() + 2e9,
                m_states: 2e9,
                m_token: preset.act_bytes_per_token(),
            },
        };
        let sch = dhp::scheduler::Scheduler::new(cost, mesh.clone());
        let kind = *rng.choose(&DatasetKind::all());
        let mut sampler = DatasetSampler::new(kind, rng.next_u64());
        let seqs = sampler.sample_batch(rng.range_usize(1, 48));
        let schedule = sch.schedule(&seqs);
        schedule
            .validate(&seqs, n)
            .map_err(|e| format!("{e} (occupied {}/{n})", occupied.len()))?;
        for wave in &schedule.waves {
            if wave.total_degree() > free {
                return Err(format!(
                    "wave spends {} ranks but only {free} are free",
                    wave.total_degree()
                ));
            }
            for g in &wave.groups {
                for &r in &g.ranks {
                    if !mesh.is_rank_free(r) {
                        return Err(format!("occupied rank {r} placed"));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn group_pool_hit_rate_rises_across_scheduled_steps() {
    // Regression for the reuse-aware placement policy: on a stationary
    // workload, consecutive scheduled steps must key into an increasingly
    // warm pool — the late-window hit-rate strictly exceeds the early
    // window's, and after a 10-step warmup it clears 0.8.
    use dhp::cluster::{ClusterSim, CommKind};
    use dhp::experiments::harness::ExpContext;
    use dhp::scheduler::Schedule;

    let mut ctx = ExpContext::new(
        by_name("InternVL3-8B").unwrap(),
        DatasetKind::OpenVid,
        16,
        TrainStage::Full,
    );
    ctx.seed = 0xA11A;
    let sch = ctx.dhp();
    let sim: ClusterSim = ctx.sim();
    let planner = ctx.micro_batch_planner();
    let mut sampler = ctx.sampler();
    let mut pool = GroupPool::new();

    let mut windows: Vec<(u64, u64)> = Vec::new(); // (hits, misses) per step
    for step in 0..15u64 {
        let batch = GlobalBatch {
            step,
            sequences: sampler.sample_batch(48),
        };
        let scheduled: Vec<(Vec<Sequence>, Schedule)> = planner
            .plan(&batch)
            .iter()
            .map(|mb| (mb.sequences.clone(), sch.schedule(&mb.sequences)))
            .collect();
        let before = pool.stats();
        sim.execute_iteration(&scheduled, CommKind::RingCp, &mut pool);
        let after = pool.stats();
        windows.push((after.hits - before.hits, after.misses - before.misses));
    }
    let rate = |w: &[(u64, u64)]| -> f64 {
        let hits: u64 = w.iter().map(|x| x.0).sum();
        let misses: u64 = w.iter().map(|x| x.1).sum();
        hits as f64 / (hits + misses).max(1) as f64
    };
    let early = rate(&windows[..3]);
    let late = rate(&windows[10..]);
    assert!(
        late > early,
        "hit-rate did not rise: early {early:.3} vs late {late:.3} ({windows:?})"
    );
    assert!(
        late > 0.8,
        "post-warmup hit-rate {late:.3} below 0.8 ({windows:?})"
    );
}

#[test]
fn parallel_state_reconfigure_is_sound_and_pooled() {
    forall(100, 0xA111, |rng| {
        let cluster = rand_cluster(rng);
        let mesh = DeviceMesh::new(&cluster);
        let n = mesh.replicas;
        let mut st = ParallelState::new(mesh, cluster.tp, cluster.pp);
        let mut prev_pool = 0usize;
        for round in 0..4 {
            let mut degrees = Vec::new();
            let mut left = n;
            while left > 0 {
                let d = rng.range_usize(1, left + 1);
                degrees.push(d);
                left -= d;
            }
            st.reconfigure_cp(&degrees)
                .map_err(|e| format!("round {round}: {e}"))?;
            // Full coverage: every rank in exactly one group.
            if !st.idle_ranks().is_empty() {
                return Err(format!("idle ranks after full plan: {:?}", st.idle_ranks()));
            }
            // The pool only ever grows, never re-creates.
            let pool_now = st.pool_size();
            if pool_now < prev_pool {
                return Err("pool shrank".into());
            }
            prev_pool = pool_now;
        }
        Ok(())
    });
}

#[test]
fn group_pool_is_idempotent_under_any_acquire_sequence() {
    forall(100, 0xA112, |rng| {
        let mut pool = GroupPool::new();
        let mut reference: std::collections::HashSet<Vec<usize>> =
            Default::default();
        for _ in 0..rng.range_usize(1, 40) {
            let len = rng.range_usize(1, 8);
            let mut ranks: Vec<usize> =
                (0..len).map(|_| rng.range_usize(0, 16)).collect();
            let g = pool.acquire(GroupKind::ContextParallel, ranks.clone());
            // Group identity is the canonical sorted-dedup set.
            ranks.sort_unstable();
            ranks.dedup();
            if g.ranks != ranks {
                return Err(format!("{:?} != {ranks:?}", g.ranks));
            }
            reference.insert(ranks);
        }
        if pool.len() != reference.len() {
            return Err(format!(
                "pool has {} unique groups, expected {}",
                pool.len(),
                reference.len()
            ));
        }
        let s = pool.stats();
        if s.misses as usize != reference.len() {
            return Err(format!("misses {} != unique {}", s.misses, reference.len()));
        }
        Ok(())
    });
}

#[test]
fn pool_never_evicts_while_capacity_remains() {
    // ISSUE-3 acceptance property: under ANY acquire sequence against a
    // group-count cap, eviction happens only when the pool is genuinely
    // full of distinct groups — never while unbounded capacity remains —
    // and the occupancy respects the cap throughout. Conservation: every
    // miss is either a first-time creation or a re-creation of a
    // previously evicted group.
    use dhp::parallel::PoolCapacity;
    forall(150, 0xA11B, |rng| {
        let cap = rng.range_usize(1, 10);
        let mut pool = GroupPool::with_capacity(PoolCapacity::MaxGroups(cap));
        let mut unique: std::collections::HashSet<Vec<usize>> = Default::default();
        for _ in 0..rng.range_usize(1, 60) {
            let len = rng.range_usize(1, 6);
            let mut ranks: Vec<usize> =
                (0..len).map(|_| rng.range_usize(0, 12)).collect();
            pool.acquire(GroupKind::ContextParallel, ranks.clone());
            ranks.sort_unstable();
            ranks.dedup();
            unique.insert(ranks);
            let s = pool.stats();
            if unique.len() <= cap && s.evictions != 0 {
                return Err(format!(
                    "evicted {} groups while only {} of {cap} slots were ever \
                     needed",
                    s.evictions,
                    unique.len()
                ));
            }
            if pool.len() > cap {
                return Err(format!("occupancy {} exceeds cap {cap}", pool.len()));
            }
            if s.misses != unique.len() as u64 + s.evicted_recreations {
                return Err(format!(
                    "miss conservation broken: {} misses, {} unique, {} \
                     re-creations",
                    s.misses,
                    unique.len(),
                    s.evicted_recreations
                ));
            }
        }
        Ok(())
    });
    // And the unbounded pool never evicts at all, under the same traffic.
    forall(50, 0xA11C, |rng| {
        let mut pool = GroupPool::new();
        for _ in 0..rng.range_usize(1, 60) {
            let len = rng.range_usize(1, 6);
            let ranks: Vec<usize> =
                (0..len).map(|_| rng.range_usize(0, 12)).collect();
            pool.acquire(GroupKind::ContextParallel, ranks);
        }
        let s = pool.stats();
        if s.evictions != 0 || s.evicted_recreations != 0 {
            return Err(format!("unbounded pool evicted: {s:?}"));
        }
        Ok(())
    });
}

#[test]
fn micro_batch_planner_partitions_any_stream() {
    forall(100, 0xA113, |rng| {
        let preset = rng.choose(&PRESETS).clone();
        let mm = MemoryModel::new(&preset, 128e9, 16);
        let planner = MicroBatchPlanner::new(
            rng.range_usize(2, 32),
            mm.rank_budget(),
            mm.m_token,
        );
        let kind = *rng.choose(&DatasetKind::all());
        let mut sampler = DatasetSampler::new(kind, rng.next_u64()).with_spec(
            TokenizerSpec {
                fps: 2.0,
                tokens_per_frame: 256.0,
                text_min: 32,
                text_max: 512,
            },
        );
        let batch = GlobalBatch {
            step: 0,
            sequences: sampler.sample_batch(rng.range_usize(1, 128)),
        };
        let mbs = planner.plan(&batch);
        // Exact partition, order preserved.
        let flat: Vec<u64> = mbs
            .iter()
            .flat_map(|mb| mb.sequences.iter().map(|s| s.id))
            .collect();
        let orig: Vec<u64> = batch.sequences.iter().map(|s| s.id).collect();
        if flat != orig {
            return Err("partition broke order/coverage".into());
        }
        for mb in &mbs {
            let bytes: f64 = mb
                .sequences
                .iter()
                .map(|s| s.act_bytes(planner.m_token))
                .sum();
            if bytes > planner.capacity_bytes() && mb.sequences.len() > 1 {
                return Err("oversized multi-sequence micro-batch".into());
            }
        }
        Ok(())
    });
}

#[test]
fn session_apply_rejects_atomically_under_adversarial_traces() {
    // ISSUE-6 acceptance property: a rejected MeshEvent trace must leave
    // the session EXACTLY as it was. We drive a "dirty" session with
    // adversarial traces (out-of-range ranks, double-occupies, releases
    // of free ranks, all-occupying traces — several with a VALID prefix,
    // so rejection must also roll that prefix back) and a "clean" twin
    // that never sees them; after every Err, the next step's digest must
    // match the twin's bit-for-bit.
    use dhp::experiments::harness::ExpContext;
    use dhp::session::MeshEvent;
    forall(10, 0xA7DC, |rng| {
        let npus = *rng.choose(&[16usize, 32]);
        let mut ctx = ExpContext::new(
            by_name("InternVL3-2B").unwrap(),
            DatasetKind::OpenVid,
            npus,
            TrainStage::Full,
        )
        .with_gbs(16);
        ctx.seed = rng.next_u64();
        let mut dirty = ctx.session_for(Box::new(ctx.dhp()));
        let mut clean = ctx.session_for(Box::new(ctx.dhp()));
        let mut sampler = ctx.sampler();
        let n = ctx.replicas();
        for round in 0..4 {
            // Occasionally move BOTH twins to the same legal occupancy,
            // so the adversarial traces also hit fragmented meshes.
            if rng.bool(0.5) {
                let free: Vec<usize> =
                    (0..n).filter(|&r| dirty.mesh().is_rank_free(r)).collect();
                if free.len() > 2 {
                    let legal = vec![MeshEvent::Occupy(vec![free[0]])];
                    dirty.apply(&legal).map_err(|e| format!("{e}"))?;
                    clean.apply(&legal).map_err(|e| format!("{e}"))?;
                }
            }
            let free: Vec<usize> =
                (0..n).filter(|&r| dirty.mesh().is_rank_free(r)).collect();
            let held: Vec<usize> =
                (0..n).filter(|&r| !dirty.mesh().is_rank_free(r)).collect();
            let trace = match rng.range_usize(0, 5) {
                // Out-of-range rank.
                0 => vec![MeshEvent::Occupy(vec![n + rng.range_usize(0, 4)])],
                // Double-occupy of the same rank within one event.
                1 => vec![MeshEvent::Occupy(vec![free[0], free[0]])],
                // Valid prefix, then a release of a rank nobody holds.
                2 => vec![
                    MeshEvent::Occupy(vec![free[0]]),
                    MeshEvent::Release(vec![*rng.choose(&free[1..])]),
                ],
                // Occupying every free rank leaves nothing to schedule.
                3 => vec![MeshEvent::Occupy(free.clone())],
                // Valid release prefix, then out-of-range; or, on a
                // fully free mesh, a release of an unheld rank.
                _ => match held.first() {
                    Some(&h) => vec![
                        MeshEvent::Release(vec![h]),
                        MeshEvent::Occupy(vec![n]),
                    ],
                    None => vec![MeshEvent::Release(vec![free[0]])],
                },
            };
            if dirty.apply(&trace).is_ok() {
                return Err(format!(
                    "round {round}: adversarial trace {trace:?} was accepted"
                ));
            }
            let batch = sampler.sample_batch(ctx.gbs);
            let a = dirty.step(&batch);
            let b = clean.step(&batch);
            if a.digest() != b.digest() {
                return Err(format!(
                    "round {round}: digests diverged after rejected trace \
                     {trace:?}: {:#018x} vs {:#018x}",
                    a.digest(),
                    b.digest()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn cost_model_monotonicities() {
    forall(200, 0xA114, |rng| {
        let preset = rng.choose(&PRESETS).clone();
        let hw = HardwareSpec::default();
        let cm = CostModel {
            coeffs: CostCoeffs::analytic(&preset, TrainStage::Full, &hw),
            memory: MemoryModel::new(&preset, 256e9, 16),
        };
        let lv = rng.range_u64(16, 60_000);
        let lt = rng.range_u64(16, 512);
        let seq = Sequence::new(0, lv, lt);
        let agg = WorkloadAgg::of(std::slice::from_ref(&seq));
        let d = rng.range_usize(2, 64);
        // More bandwidth never hurts.
        let slow = cm.t_total(&agg, d, 12.5e9);
        let fast = cm.t_total(&agg, d, 196e9);
        if fast > slow + 1e-12 {
            return Err(format!("bw monotonicity: {fast} > {slow}"));
        }
        // More tokens never cost less (same degree, same bandwidth).
        let bigger = Sequence::new(1, lv + 1024, lt);
        let agg2 = WorkloadAgg::of(std::slice::from_ref(&bigger));
        if cm.t_total(&agg2, d, 12.5e9) < slow {
            return Err("token monotonicity violated".into());
        }
        // Memory min-degree is monotone in tokens.
        if cm.memory.min_degree(bigger.len()) < cm.memory.min_degree(seq.len()) {
            return Err("min_degree not monotone".into());
        }
        Ok(())
    });
}

#[test]
fn schedules_respect_memory_constraint_eq3() {
    // Every group in every DHP plan satisfies Eq. 3:
    // Σ tokens · M_token ≤ d · E′.
    forall(40, 0xA115, |rng| {
        let preset = by_name("InternVL3-8B").unwrap();
        let cluster = {
            let mut c = ClusterConfig::default().with_npus(32);
            c.tp = 2;
            c.pp = 2;
            c
        };
        let hw = HardwareSpec {
            peak_flops: 376e12 * 4.0,
            ..HardwareSpec::default()
        };
        let memory = MemoryModel::new(
            &preset,
            cluster.mem_bytes as f64 * cluster.tp as f64,
            cluster.replicas(),
        );
        let cost = CostModel {
            coeffs: CostCoeffs::analytic(&preset, TrainStage::Full, &hw),
            memory: memory.clone(),
        };
        let sch = dhp::scheduler::Scheduler::new(cost, DeviceMesh::new(&cluster));
        let mut sampler = DatasetSampler::new(DatasetKind::OpenVid, rng.next_u64())
            .with_spec(TokenizerSpec {
                fps: 2.0,
                tokens_per_frame: 256.0,
                text_min: 32,
                text_max: 512,
            });
        let seqs = sampler.sample_batch(rng.range_usize(1, 48));
        let schedule = sch.schedule(&seqs);
        schedule.validate(&seqs, cluster.replicas()).map_err(|e| e.to_string())?;
        for plan in &schedule.waves {
            for g in &plan.groups {
                let tokens: u64 = g.seq_idxs.iter().map(|&i| seqs[i].len()).sum();
                // Allow the clamped case: a sequence too big for the whole
                // cluster is scheduled anyway (real system would OOM).
                if !memory.fits(tokens, g.degree)
                    && memory.min_degree(tokens) <= cluster.replicas()
                {
                    return Err(format!(
                        "Eq.3 violated: {tokens} tokens at degree {}",
                        g.degree
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn quiet_event_kernel_is_digest_identical_to_step_granular() {
    // ISSUE-8 acceptance: over random clusters, batch sizes, fault
    // seeds, and pool capacities, the discrete-event execution kernel
    // (`within_step_faults(true)`) with a quiet injector produces
    // bit-identical `StepReport::digest()` sequences to the retained
    // step-granular path. Any divergence means the event kernel's
    // re-ordering of the same arithmetic leaked into the numbers.
    use dhp::cluster::{FaultConfig, FaultInjector};
    use dhp::experiments::harness::ExpContext;
    use dhp::parallel::PoolCapacity;

    forall(8, 0xA117, |rng| {
        let npus = *rng.choose(&[16usize, 32]);
        let gbs = rng.range_usize(8, 33);
        let seed = rng.next_u64();
        let cap = match rng.range_usize(0, 3) {
            0 => PoolCapacity::Unbounded,
            1 => PoolCapacity::MaxGroups(rng.range_usize(2, 8)),
            _ => PoolCapacity::BufferBytes(rng.range_u64(1 << 27, 1 << 31)),
        };
        let mut ctx = ExpContext::new(
            by_name("InternVL3-2B").unwrap(),
            DatasetKind::OpenVid,
            npus,
            TrainStage::Full,
        )
        .with_gbs(gbs);
        ctx.seed = seed;
        let steps = 3usize;
        let digests = |within: bool| -> Vec<u64> {
            let mut session = ctx
                .session_builder_for(Box::new(ctx.dhp()))
                .pool_capacity(cap)
                .fault_injector(FaultInjector::new(
                    ctx.replicas(),
                    FaultConfig::quiet(seed),
                ))
                .within_step_faults(within)
                .build();
            let mut sampler = ctx.sampler();
            (0..steps)
                .map(|_| session.step(&sampler.sample_batch(ctx.gbs)).digest())
                .collect()
        };
        let ev = digests(true);
        let st = digests(false);
        if ev != st {
            return Err(format!(
                "event kernel drifted from the step-granular path: \
                 {ev:#x?} vs {st:#x?} \
                 (npus {npus}, gbs {gbs}, cap {cap:?}, seed {seed:#x})"
            ));
        }
        Ok(())
    });
}
