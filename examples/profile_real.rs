//! Profiler over the REAL runtime: times PJRT-CPU executions of the
//! AOT-lowered model at swept sequence lengths and fits the paper's Eq. 8
//! cost-model coefficients from the measurements — the Profiler workflow
//! of §5 on real execution data.
//!
//! ```bash
//! make artifacts   # once
//! cargo run --release --example profile_real
//! ```

use std::path::Path;

use dhp::experiments::estimator::fit_from_runtime;

fn main() -> anyhow::Result<()> {
    dhp::util::logger::init();
    let dir = Path::new("artifacts");
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "artifacts/ missing — run `make artifacts` first"
    );
    println!("profiling AOT model executions on PJRT-CPU (3 reps/bucket)...");
    let (coeffs, fit) = fit_from_runtime(dir, 3)?;
    println!("fitted Eq. 8 coefficients from real executions:");
    println!("  alpha1 (s/token^2) = {:.4e}", coeffs.alpha1);
    println!("  alpha2 (s/token)   = {:.4e}", coeffs.alpha2);
    println!("  beta1  (s fixed)   = {:.4e}", coeffs.beta1);
    println!(
        "fit quality: MAPE {:.2}% over {} buckets, R^2 {:.4}",
        fit.mape, fit.n, fit.r_squared
    );
    println!(
        "(paper Table 3 reports 4.1-7.9% estimator error; sub-8% here \
         means the fitted model predicts real PJRT runtimes within the \
         paper's band)"
    );
    Ok(())
}
