//! END-TO-END VALIDATION DRIVER (DESIGN.md §5): train the real
//! ~100M-parameter JAX MLLM through the full three-layer stack —
//!
//!   L1 Pallas flash-attention kernel (inside the AOT HLO)
//!   L2 JAX model, lowered once to HLO text by `make artifacts`
//!   L3 this Rust coordinator: PJRT execution, Adam, and the DHP
//!      scheduler planning each batch asynchronously on a simulated
//!      cluster while the real gradients compute
//!
//! ```bash
//! make artifacts   # once
//! cargo run --release --example e2e_train -- [--steps 220] [--lr 0.001]
//! ```
//!
//! The loss curve lands in e2e_loss.csv and EXPERIMENTS.md §E2E.

use std::path::PathBuf;

use dhp::train::{run, AdamConfig, TrainerConfig};
use dhp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    dhp::util::logger::init();
    let args = Args::parse(std::env::args().skip(1))?;
    let cfg = TrainerConfig {
        artifacts_dir: PathBuf::from(args.str_or("artifacts", "artifacts")),
        artifact: "e2e_grad.hlo.txt".into(),
        params_file: "e2e_params.f32".into(),
        steps: args.usize_or("steps", 220)?,
        adam: AdamConfig {
            lr: args.f64_or("lr", 1e-3)? as f32,
            ..Default::default()
        },
        seed: args.u64_or("seed", 0xE2E)?,
        log_path: Some(PathBuf::from(args.str_or("log", "e2e_loss.csv"))),
        sim_npus: args.usize_or("sim-npus", 8)?,
        pool_capacity: match args.usize_or("pool-cap", 0)? {
            0 => dhp::parallel::PoolCapacity::Unbounded,
            n => dhp::parallel::PoolCapacity::MaxGroups(n),
        },
    };
    let report = run(&cfg)?;

    println!("\n=== end-to-end validation ===");
    println!(
        "model: {} parameters, {} steps, {:.1}s wall",
        report.param_count,
        report.records.len(),
        report.total_time_s
    );
    println!(
        "loss: {:.4} -> {:.4} (tail-10 mean {:.4}; random-init baseline ln(8192)={:.3})",
        report.first_loss(),
        report.last_loss(),
        report.tail_mean_loss(10),
        (8192f32).ln()
    );
    let hidden = report
        .records
        .iter()
        .filter(|r| r.schedule_latency_s < r.step_time_s)
        .count();
    println!(
        "DHP scheduling hidden behind compute in {hidden}/{} steps \
         (mean latency {:.2} ms vs mean step {:.2} s)",
        report.records.len(),
        report
            .records
            .iter()
            .map(|r| r.schedule_latency_s)
            .sum::<f64>()
            / report.records.len() as f64
            * 1e3,
        report
            .records
            .iter()
            .map(|r| r.step_time_s)
            .sum::<f64>()
            / report.records.len() as f64,
    );
    anyhow::ensure!(
        report.tail_mean_loss(10) < report.first_loss() - 1.0,
        "loss did not improve — e2e validation FAILED"
    );
    println!("e2e validation PASSED: loss decreased by > 1 nat");
    Ok(())
}
