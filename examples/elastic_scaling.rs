//! Domain scenario: elastic cluster scaling. A training job is moved
//! across cluster sizes (8 → 64 NPUs) without retuning: DHP adapts its
//! parallelism automatically while static baselines would need manual
//! re-tuning at every size (we re-tune them anyway — DHP still wins).
//!
//! Also demonstrates the asynchronous scheduling pipeline: plans for step
//! t+1 are produced on a CPU thread while step t "executes".
//!
//! ```bash
//! cargo run --release --example elastic_scaling
//! ```

use dhp::config::presets::by_name;
use dhp::config::TrainStage;
use dhp::data::datasets::DatasetKind;
use dhp::experiments::harness::{run_policy, ExpContext, PolicySet};
use dhp::report::Table;
use dhp::scheduler::pipeline::SchedulePipeline;
use dhp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    dhp::util::logger::init();
    let args = Args::parse(std::env::args().skip(1))?;
    let gbs = args.usize_or("gbs", 256)?;

    let mut table = Table::new(
        "elastic scaling: per-device throughput as the cluster grows",
        &[
            "NPUs",
            "replicas",
            "DHP tok/s/dev",
            "best-static tok/s/dev",
            "DHP advantage",
            "scaling eff.",
        ],
    );
    let mut base: Option<f64> = None;
    for npus in [8usize, 16, 32, 64] {
        let ctx = ExpContext::new(
            by_name("Qwen3VL-8B").unwrap(),
            DatasetKind::OpenVid,
            npus,
            TrainStage::Full,
        )
        .with_gbs(gbs)
        .with_steps(1, 3);
        let set = PolicySet::build(&ctx);
        let dhp = run_policy(&ctx, &set.dhp);
        let mega = run_policy(&ctx, &set.megatron);
        let ds = run_policy(&ctx, &set.deepspeed);
        let best_static = mega
            .tokens_per_s_per_device
            .max(ds.tokens_per_s_per_device);
        let eff = match base {
            None => {
                base = Some(dhp.tokens_per_s_per_device);
                1.0
            }
            Some(b) => dhp.tokens_per_s_per_device / b,
        };
        table.row(vec![
            npus.to_string(),
            ctx.replicas().to_string(),
            format!("{:.0}", dhp.tokens_per_s_per_device),
            format!("{best_static:.0}"),
            format!("{:.2}x", dhp.tokens_per_s_per_device / best_static),
            format!("{:.0}%", eff * 100.0),
        ]);
    }
    table.print();

    // Async pipeline demo: scheduling latency hides behind compute.
    println!("\nasync scheduling pipeline (one step lookahead):");
    let ctx = ExpContext::new(
        by_name("Qwen3VL-8B").unwrap(),
        DatasetKind::OpenVid,
        32,
        TrainStage::Full,
    );
    let pipe = SchedulePipeline::spawn(ctx.dhp(), 1);
    let mut sampler = ctx.sampler();
    pipe.submit(0, sampler.sample_batch(64));
    for step in 0..4u64 {
        if step < 3 {
            pipe.submit(step + 1, sampler.sample_batch(64));
        }
        // Simulated accelerator compute for the current step.
        std::thread::sleep(std::time::Duration::from_millis(20));
        let done = pipe.recv().expect("schedule");
        println!(
            "  step {}: plan ready (latency {:.2} ms, solver {:.2} ms, \
             group prewarm {:.0} ms, pool hit-rate {:.2}) — hidden: {}",
            done.step,
            done.schedule_latency_s * 1e3,
            done.schedule.solve_time_s * 1e3,
            done.reconfig_serial_s * 1e3,
            done.pool.hit_rate(),
            done.schedule_latency_s < 0.020,
        );
    }
    pipe.shutdown();
    Ok(())
}
