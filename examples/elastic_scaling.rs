//! Domain scenario: elastic cluster scaling. A training job is moved
//! across cluster sizes (8 → 64 NPUs) without retuning: DHP adapts its
//! parallelism automatically while static baselines would need manual
//! re-tuning at every size (we re-tune them anyway — DHP still wins).
//!
//! Also demonstrates elastic co-tenancy through the `DhpSession` façade:
//! a concurrent job claims ranks mid-run via live `MeshEvent`s, the
//! session re-snapshots the fabric, and the very next solve adapts to
//! the fragmented mesh — no rebuild, no retuning.
//!
//! ```bash
//! cargo run --release --example elastic_scaling
//! ```

use dhp::config::presets::by_name;
use dhp::config::TrainStage;
use dhp::data::datasets::DatasetKind;
use dhp::experiments::harness::{run_policy, ExpContext, PolicySet};
use dhp::report::Table;
use dhp::session::MeshEvent;
use dhp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    dhp::util::logger::init();
    let args = Args::parse(std::env::args().skip(1))?;
    let gbs = args.usize_or("gbs", 256)?;

    let mut table = Table::new(
        "elastic scaling: per-device throughput as the cluster grows",
        &[
            "NPUs",
            "replicas",
            "DHP tok/s/dev",
            "best-static tok/s/dev",
            "DHP advantage",
            "scaling eff.",
        ],
    );
    let mut base: Option<f64> = None;
    for npus in [8usize, 16, 32, 64] {
        let ctx = ExpContext::new(
            by_name("Qwen3VL-8B").unwrap(),
            DatasetKind::OpenVid,
            npus,
            TrainStage::Full,
        )
        .with_gbs(gbs)
        .with_steps(1, 3);
        let set = PolicySet::build(&ctx);
        let dhp = run_policy(&ctx, &set.dhp);
        let mega = run_policy(&ctx, &set.megatron);
        let ds = run_policy(&ctx, &set.deepspeed);
        let best_static = mega
            .tokens_per_s_per_device
            .max(ds.tokens_per_s_per_device);
        let eff = match base {
            None => {
                base = Some(dhp.tokens_per_s_per_device);
                1.0
            }
            Some(b) => dhp.tokens_per_s_per_device / b,
        };
        table.row(vec![
            npus.to_string(),
            ctx.replicas().to_string(),
            format!("{:.0}", dhp.tokens_per_s_per_device),
            format!("{best_static:.0}"),
            format!("{:.2}x", dhp.tokens_per_s_per_device / best_static),
            format!("{:.0}%", eff * 100.0),
        ]);
    }
    table.print();

    // Elastic co-tenancy demo: a concurrent job claims one rank per node
    // mid-run. The session's live mesh-event feed re-snapshots the
    // fabric, so the next solve prices the fragmentation and places only
    // on ranks this job still owns; the release restores full capacity.
    println!("\nlive mesh events (elastic co-tenancy through DhpSession):");
    let ctx = ExpContext::new(
        by_name("Qwen3VL-8B").unwrap(),
        DatasetKind::Msrvtt,
        32,
        TrainStage::Full,
    );
    let mut session = ctx.session();
    let mut sampler = ctx.sampler();
    let batch = sampler.sample_batch(24);
    let print_step = |label: &str, free: usize, r: &dhp::session::StepReport| {
        println!(
            "  {label}: {free} free replicas, fabric fp {:016x}, \
             iter {:.3}s (reconfig charged {:.1} ms / serial {:.1} ms, \
             replay {:.2})",
            r.fabric_fingerprint,
            r.iteration.iter_time_s,
            r.iteration.reconfig_time_s * 1e3,
            r.iteration.reconfig_serial_s * 1e3,
            r.replay_rate,
        );
    };
    let r = session.step(&batch);
    print_step("steady state ", session.mesh().free_replicas(), &r);

    let claimed: Vec<usize> = (0..ctx.replicas()).step_by(2).collect();
    session.apply(&[MeshEvent::Occupy(claimed.clone())])?;
    let r = session.step(&batch);
    print_step("co-tenant in ", session.mesh().free_replicas(), &r);
    for schedule in &r.schedules {
        for wave in &schedule.waves {
            for g in &wave.groups {
                assert!(g.ranks.iter().all(|rank| !claimed.contains(rank)));
            }
        }
    }

    session.apply(&[MeshEvent::Release(claimed)])?;
    let r = session.step(&batch);
    print_step("co-tenant out", session.mesh().free_replicas(), &r);
    Ok(())
}
