//! Domain scenario: large-scale video-text pretraining (the workload the
//! paper's intro motivates). Simulates several training iterations of
//! InternVL3-8B on OpenVid-like data at 64 NPUs, comparing DHP against
//! tuned Megatron-LM and DeepSpeed baselines — with per-iteration detail
//! the aggregate figures don't show.
//!
//! ```bash
//! cargo run --release --example video_pretrain -- [--npus 64] [--gbs 512]
//! ```

use dhp::baselines::SchedulePolicy;
use dhp::config::presets::by_name;
use dhp::config::TrainStage;
use dhp::data::datasets::DatasetKind;
use dhp::experiments::harness::{ExpContext, PolicySet};
use dhp::report::Table;
use dhp::session::StepReport;
use dhp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    dhp::util::logger::init();
    let args = Args::parse(std::env::args().skip(1))?;
    let npus = args.usize_or("npus", 64)?;
    let gbs = args.usize_or("gbs", 512)?;
    let iterations = args.usize_or("iterations", 5)?;

    let ctx = ExpContext::new(
        by_name(args.str_or("model", "InternVL3-8B"))
            .ok_or_else(|| anyhow::anyhow!("unknown --model"))?,
        DatasetKind::OpenVid,
        npus,
        TrainStage::Full,
    )
    .with_gbs(gbs);

    println!(
        "video pretraining: {} on OpenVid, {npus} NPUs ({} replicas), GBS {gbs}",
        ctx.preset.name,
        ctx.replicas()
    );
    let set = PolicySet::build(&ctx);
    println!(
        "tuned baselines: Megatron CP={}, DeepSpeed-Ulysses SP={}",
        set.megatron.degree,
        set.deepspeed.degree()
    );

    let mut sampler = ctx.sampler();
    // One persistent session per policy: each owns its mesh, scheduling
    // pipeline, and communication-group pool, so reconfiguration cost
    // (pool misses) is charged into each iteration and group reuse across
    // iterations is part of the measurement. The first step warm-starts
    // the pool (paper §5's pre-training group creation).
    let mut sessions = [
        ctx.session_for(set.megatron.clone_policy()),
        ctx.session_for(set.deepspeed.clone_policy()),
        ctx.session_for(set.dhp.clone_policy()),
    ];

    let mut table = Table::new(
        "per-iteration time (s) and DHP plan",
        &["iter", "tokens", "Megatron", "DeepSpeed", "DHP", "speedup", "DHP degrees"],
    );
    let mut totals = [0.0f64; 3];
    for iter in 0..iterations {
        let seqs = sampler.sample_batch(gbs);
        let reports: Vec<StepReport> =
            sessions.iter_mut().map(|s| s.step(&seqs)).collect();
        let (t_mega, t_ds, t_dhp) = (
            reports[0].iteration.iter_time_s,
            reports[1].iteration.iter_time_s,
            reports[2].iteration.iter_time_s,
        );
        let mut degrees: Vec<usize> = reports[2]
            .schedules
            .iter()
            .flat_map(|s| s.degree_multiset())
            .collect();
        degrees.sort_unstable_by(|a, b| b.cmp(a));
        degrees.dedup();
        totals[0] += t_mega;
        totals[1] += t_ds;
        totals[2] += t_dhp;
        table.row(vec![
            iter.to_string(),
            reports[2].iteration.tokens.to_string(),
            format!("{t_mega:.2}"),
            format!("{t_ds:.2}"),
            format!("{t_dhp:.2}"),
            format!("{:.2}x", t_mega.min(t_ds) / t_dhp),
            format!("{degrees:?}"),
        ]);
    }
    table.print();
    println!(
        "totals over {iterations} iterations: Megatron {:.1}s, DeepSpeed {:.1}s, \
         DHP {:.1}s -> overall speedup {:.2}x vs best baseline",
        totals[0],
        totals[1],
        totals[2],
        totals[0].min(totals[1]) / totals[2]
    );
    Ok(())
}
