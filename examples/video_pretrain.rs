//! Domain scenario: large-scale video-text pretraining (the workload the
//! paper's intro motivates). Simulates several training iterations of
//! InternVL3-8B on OpenVid-like data at 64 NPUs, comparing DHP against
//! tuned Megatron-LM and DeepSpeed baselines — with per-iteration detail
//! the aggregate figures don't show.
//!
//! ```bash
//! cargo run --release --example video_pretrain -- [--npus 64] [--gbs 512]
//! ```

use dhp::baselines::SchedulePolicy;
use dhp::config::presets::by_name;
use dhp::config::TrainStage;
use dhp::data::batch::GlobalBatch;
use dhp::data::datasets::DatasetKind;
use dhp::data::sequence::Sequence;
use dhp::experiments::harness::{ExpContext, PolicySet};
use dhp::report::Table;
use dhp::scheduler::Schedule;
use dhp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    dhp::util::logger::init();
    let args = Args::parse(std::env::args().skip(1))?;
    let npus = args.usize_or("npus", 64)?;
    let gbs = args.usize_or("gbs", 512)?;
    let iterations = args.usize_or("iterations", 5)?;

    let ctx = ExpContext::new(
        by_name(args.str_or("model", "InternVL3-8B"))
            .ok_or_else(|| anyhow::anyhow!("unknown --model"))?,
        DatasetKind::OpenVid,
        npus,
        TrainStage::Full,
    )
    .with_gbs(gbs);

    println!(
        "video pretraining: {} on OpenVid, {npus} NPUs ({} replicas), GBS {gbs}",
        ctx.preset.name,
        ctx.replicas()
    );
    let set = PolicySet::build(&ctx);
    println!(
        "tuned baselines: Megatron CP={}, DeepSpeed-Ulysses SP={}",
        set.megatron.degree,
        set.deepspeed.degree()
    );

    let planner = ctx.micro_batch_planner();
    let sim = ctx.sim();
    let mut sampler = ctx.sampler();
    // One persistent communication-group pool per policy: reconfiguration
    // cost (pool misses) is charged into each iteration, so group reuse
    // across iterations is part of the measurement.
    let mut pools = [
        dhp::parallel::GroupPool::new(),
        dhp::parallel::GroupPool::new(),
        dhp::parallel::GroupPool::new(),
    ];

    let mut table = Table::new(
        "per-iteration time (s) and DHP plan",
        &["iter", "tokens", "Megatron", "DeepSpeed", "DHP", "speedup", "DHP degrees"],
    );
    let mut totals = [0.0f64; 3];
    for iter in 0..iterations {
        let batch = GlobalBatch {
            step: iter as u64,
            sequences: sampler.sample_batch(gbs),
        };
        let mbs = planner.plan(&batch);
        let run = |policy: &dyn SchedulePolicy,
                   pool: &mut dhp::parallel::GroupPool|
         -> (f64, Vec<usize>) {
            let scheduled: Vec<(Vec<Sequence>, Schedule)> = mbs
                .iter()
                .map(|mb| (mb.sequences.clone(), policy.schedule(&mb.sequences)))
                .collect();
            if iter == 0 {
                // Warm pool at training start (paper §5).
                dhp::experiments::harness::prewarm_from_schedules(pool, &scheduled);
            }
            let degrees = scheduled
                .iter()
                .flat_map(|(_, s)| s.degree_multiset())
                .collect();
            (
                sim.execute_iteration(&scheduled, policy.comm_kind(), pool)
                    .iter_time_s,
                degrees,
            )
        };
        let [pool_mega, pool_ds, pool_dhp] = &mut pools;
        let (t_mega, _) = run(&set.megatron, pool_mega);
        let (t_ds, _) = run(&set.deepspeed, pool_ds);
        let (t_dhp, mut degrees) = run(&set.dhp, pool_dhp);
        degrees.sort_unstable_by(|a, b| b.cmp(a));
        degrees.dedup();
        totals[0] += t_mega;
        totals[1] += t_ds;
        totals[2] += t_dhp;
        table.row(vec![
            iter.to_string(),
            batch.total_tokens().to_string(),
            format!("{t_mega:.2}"),
            format!("{t_ds:.2}"),
            format!("{t_dhp:.2}"),
            format!("{:.2}x", t_mega.min(t_ds) / t_dhp),
            format!("{degrees:?}"),
        ]);
    }
    table.print();
    println!(
        "totals over {iterations} iterations: Megatron {:.1}s, DeepSpeed {:.1}s, \
         DHP {:.1}s -> overall speedup {:.2}x vs best baseline",
        totals[0],
        totals[1],
        totals[2],
        totals[0].min(totals[1]) / totals[2]
    );
    Ok(())
}
