//! Quickstart: schedule one heterogeneous multimodal micro-batch with DHP
//! and inspect the plan.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use dhp::config::presets::by_name;
use dhp::config::TrainStage;
use dhp::data::datasets::DatasetKind;
use dhp::experiments::harness::ExpContext;
use dhp::scheduler::format_degree_multiset;

fn main() -> anyhow::Result<()> {
    dhp::util::logger::init();

    // A 32-NPU cluster (8 nodes' worth at TP=2 × PP=2 → 8 model replicas)
    // training InternVL3-8B on OpenVid-like long-tail video data.
    let ctx = ExpContext::new(
        by_name("InternVL3-8B").unwrap(),
        DatasetKind::OpenVid,
        32,
        TrainStage::Full,
    );

    // Sample a micro-batch of heterogeneous sequences.
    let mut sampler = ctx.sampler();
    let seqs = sampler.sample_batch(24);
    println!("micro-batch lengths (tokens):");
    for s in &seqs {
        println!(
            "  seq {:>3}: {:>7} total ({} vision + {} text, {:.1}s video)",
            s.id,
            s.len(),
            s.vision_tokens,
            s.text_tokens,
            s.duration_s
        );
    }

    // Run the two-stage DHP scheduler: BFD packing + 2D-DP allocation.
    let scheduler = ctx.dhp();
    let schedule = scheduler.schedule(&seqs);
    schedule.validate(&seqs, ctx.replicas())?;

    println!(
        "\nDHP plan ({} replicas, solver {:.2} ms):",
        ctx.replicas(),
        schedule.solve_time_s * 1e3
    );
    for (wi, wave) in schedule.waves.iter().enumerate() {
        println!("  wave {wi} (est makespan {:.3}s):", wave.est_makespan_s);
        for g in &wave.groups {
            println!(
                "    CP degree {} on ranks {:?} ({:.0} GB/s ring) <- {} seqs, \
                 {:.0} tokens (est {:.3}s)",
                g.degree,
                g.ranks,
                g.ring_bw / 1e9,
                g.seq_idxs.len(),
                g.agg.tokens,
                g.est_time_s
            );
        }
    }
    println!(
        "degrees: {}",
        format_degree_multiset(&schedule.degree_multiset())
    );

    // Execute on the simulated cluster for ground truth.
    let sim = ctx.sim();
    let reports = sim.execute_schedule(&seqs, &schedule, dhp::cluster::CommKind::RingCp);
    let total: f64 = reports.iter().map(|w| w.makespan_s).sum();
    println!("simulated execution: {total:.3}s over {} wave(s)", reports.len());
    Ok(())
}
