//! Quickstart: drive one DHP training step through the [`DhpSession`]
//! façade — schedule, group prewarm, and simulated execution in a single
//! call — then inspect the placed plan and the iteration report.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use dhp::config::presets::by_name;
use dhp::config::TrainStage;
use dhp::data::datasets::DatasetKind;
use dhp::experiments::harness::ExpContext;
use dhp::scheduler::format_degree_multiset;
use dhp::session::DhpSession;

fn main() -> anyhow::Result<()> {
    dhp::util::logger::init();

    // A 32-NPU cluster (8 nodes' worth at TP=2 × PP=2 → 8 model replicas)
    // training InternVL3-8B on OpenVid-like long-tail video data.
    let ctx = ExpContext::new(
        by_name("InternVL3-8B").unwrap(),
        DatasetKind::OpenVid,
        32,
        TrainStage::Full,
    );

    // Sample a batch of heterogeneous sequences.
    let mut sampler = ctx.sampler();
    let seqs = sampler.sample_batch(24);
    println!("batch lengths (tokens):");
    for s in &seqs {
        println!(
            "  seq {:>3}: {:>7} total ({} vision + {} text, {:.1}s video)",
            s.id,
            s.len(),
            s.vision_tokens,
            s.text_tokens,
            s.duration_s
        );
    }

    // The whole lifecycle — scheduler, async pipeline, group pool,
    // cluster simulator — behind one constructor and one call.
    let mut session: DhpSession = ctx.session();
    let report = session.step(&seqs);

    println!(
        "\nDHP plan ({} replicas, {} micro-batch(es), solver {:.2} ms):",
        ctx.replicas(),
        report.micro_batches,
        report.solver_time_s * 1e3
    );
    for (mi, schedule) in report.schedules.iter().enumerate() {
        for (wi, wave) in schedule.waves.iter().enumerate() {
            println!(
                "  mb {mi} wave {wi} (est makespan {:.3}s):",
                wave.est_makespan_s
            );
            for g in &wave.groups {
                println!(
                    "    CP degree {} on ranks {:?} ({:.0} GB/s ring) <- {} seqs, \
                     {:.0} tokens (est {:.3}s)",
                    g.degree,
                    g.ranks,
                    g.ring_bw / 1e9,
                    g.seq_idxs.len(),
                    g.agg.tokens,
                    g.est_time_s
                );
            }
        }
        println!(
            "  mb {mi} degrees: {}",
            format_degree_multiset(&schedule.degree_multiset())
        );
    }

    // The same step already executed on the simulated cluster.
    println!(
        "\niteration report: exec {:.3}s + grad sync {:.3}s + reconfig \
         {:.3}s charged (serial {:.3}s) = {:.3}s over {} wave(s); \
         pool hit-rate {:.2}",
        report.iteration.exec_time_s,
        report.iteration.grad_sync_s,
        report.iteration.reconfig_time_s,
        report.iteration.reconfig_serial_s,
        report.iteration.iter_time_s,
        report.iteration.waves.len(),
        report.pool.hit_rate(),
    );
    Ok(())
}
