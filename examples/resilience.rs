//! Fault-injection walkthrough: a DHP session surviving a scripted
//! incident — a rank failure, a straggler storm, a co-tenant preemption,
//! and the recoveries — with the recovery economics printed per step.
//!
//!   cargo run --example resilience

use dhp::cluster::{FaultEvent, FaultInjector};
use dhp::config::presets::by_name;
use dhp::config::TrainStage;
use dhp::data::datasets::DatasetKind;
use dhp::experiments::harness::ExpContext;

fn main() {
    let mut ctx = ExpContext::new(
        by_name("InternVL3-8B").unwrap(),
        DatasetKind::OpenVid,
        32,
        TrainStage::Full,
    )
    .with_gbs(48);
    ctx.seed = 0x5C21;

    // A recorded "incident": rank 3 dies at step 1 and is repaired at
    // step 4; rank 5 straggles through steps 2-3; a co-tenant preempts
    // ranks 0-1 at step 5 and returns them at step 7.
    let script = vec![
        vec![],
        vec![FaultEvent::RankFailure { rank: 3 }],
        vec![FaultEvent::Straggler { rank: 5, slowdown: 2.5 }],
        vec![FaultEvent::Straggler { rank: 5, slowdown: 1.8 }],
        vec![FaultEvent::Recovery { ranks: vec![3] }],
        vec![FaultEvent::Preemption { ranks: vec![0, 1], duration_steps: 2 }],
        vec![],
        vec![FaultEvent::Recovery { ranks: vec![0, 1] }],
    ];
    let steps = script.len();
    let mut session = ctx
        .session_builder_for(Box::new(ctx.dhp()))
        .fault_injector(FaultInjector::scripted(ctx.replicas(), script))
        .checkpoint_interval(3)
        .build();
    let mut sampler = ctx.sampler();

    println!(
        "DHP under a scripted incident ({} replicas, {} steps)\n",
        ctx.replicas(),
        steps
    );
    println!(
        "{:<5} {:<34} {:>5} {:>9} {:>10} {:>10} {:>10}",
        "step", "faults", "free", "iter (s)", "straggle", "recovery", "ckpt (s)"
    );
    for _ in 0..steps {
        let report = session.step(&sampler.sample_batch(ctx.gbs));
        let faults = if report.faults.is_empty() {
            "-".to_string()
        } else {
            report
                .faults
                .iter()
                .map(|f| match f {
                    FaultEvent::RankFailure { rank } => format!("fail r{rank}"),
                    FaultEvent::Straggler { rank, slowdown } => {
                        format!("straggle r{rank} x{slowdown:.1}")
                    }
                    FaultEvent::Preemption { ranks, .. } => {
                        format!("preempt {ranks:?}")
                    }
                    FaultEvent::Recovery { ranks } => format!("recover {ranks:?}"),
                })
                .collect::<Vec<_>>()
                .join(", ")
        };
        println!(
            "{:<5} {:<34} {:>5} {:>9.3} {:>10.3} {:>10.2} {:>10.2}",
            report.step,
            faults,
            session.mesh().free_replicas(),
            report.iteration.iter_time_s,
            report.iteration.straggle_s,
            report.recovery_time_s,
            report.checkpoint_time_s
        );
    }
    println!(
        "\nEvery step completed: the schedule re-solved on the survivors \
         each time the mesh changed,"
    );
    println!(
        "recovery charged checkpoint restore + group re-warm + lost work, \
         and capacity returned on repair."
    );
}
