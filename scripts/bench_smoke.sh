#!/usr/bin/env bash
# Solver-latency smoke gate: runs the solver micro-bench in --quick mode
# and prints the machine-readable record it persists at the repo root
# (BENCH_solver_micro.json, per-case mean/p50 in ms). Run it before and
# after solver changes — the schedule_* vs schedule_reference_* pairs
# measure the ISSUE-1 overhaul against the retained pre-overhaul path in
# a single invocation, so the trajectory survives across PRs.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo bench --bench solver_micro -- --quick

echo
echo "=== BENCH_solver_micro.json ==="
cat BENCH_solver_micro.json
