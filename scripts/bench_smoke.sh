#!/usr/bin/env bash
# Solver-latency smoke gate: runs the solver micro-bench in --quick mode
# and prints the machine-readable record it persists at the repo root
# (BENCH_solver_micro.json, per-case mean/p50 in ms). Run it before and
# after solver changes — the schedule_* vs schedule_reference_* pairs
# measure the ISSUE-1 overhaul against the retained pre-overhaul path in
# a single invocation, so the trajectory survives across PRs.
#
# Compare mode (the ROADMAP "solver-latency trajectory in CI" gate):
#
#   scripts/bench_smoke.sh --compare [BASELINE.json]
#
# diffs the fresh BENCH_solver_micro.json against the committed baseline
# (default: scripts/solver_micro.baseline.json) and exits non-zero when
# the gate case `schedule_gbs512_npus64` regresses by more than 10% on
# mean latency. Other shared cases only warn — they are tracked, not
# gated. If no baseline exists yet, the fresh record is installed as the
# baseline (commit it) and the gate passes.
#
# ISSUE-7 scale cases: `schedule_gbs2048_npus1024` and
# `schedule_gbs8192_npus4096` MUST be present in the fresh record
# (missing = the bench rotted, fail loudly). The npus=1024 case is also
# checked against the paper's 1 ms solver budget on p90 — warn-only
# until a committed baseline exists, a hard gate once it does.
#
# ISSUE-9 steady-state case: `schedule_steady_stream_npus1024` (a
# correlated 32-batch stream through one reuse-enabled scheduler — the
# cold-vs-cache/warm-start comparison lives in its `_hit` / `_warm` /
# `_coldref` sub-cases) must also be present.
set -euo pipefail
cd "$(dirname "$0")/.."

# Toolchain guard: this gate is meaningless without cargo, and silently
# doing nothing would let regressions ship. Fail loudly with a skip
# message instead.
if ! command -v cargo >/dev/null 2>&1; then
    echo "[bench-smoke] SKIP (FAILING): no \`cargo\` on PATH — the doc/clippy/bench gates need a Rust toolchain." >&2
    echo "[bench-smoke] Install rustup (https://rustup.rs) or run inside the toolchain container, then re-run." >&2
    exit 1
fi

COMPARE=0
BASELINE="scripts/solver_micro.baseline.json"
if [[ "${1:-}" == "--compare" ]]; then
    COMPARE=1
    [[ -n "${2:-}" ]] && BASELINE="$2"
fi

# Lint gate: warnings across every target (lib, tests, benches,
# examples) are promoted to errors so drift never accumulates unseen.
echo "=== cargo clippy (deny warnings) ==="
cargo clippy --all-targets -- -D warnings

# Doc gate: the crate carries #![warn(missing_docs)] and a documented
# public API (ISSUE-3); rustdoc warnings (missing docs on new public
# items, broken intra-doc links) are doc rot and fail the smoke gate.
echo "=== cargo doc (deny warnings) ==="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

cargo bench --bench solver_micro -- --quick

# Resilience gate (ISSUE-6 + ISSUE-8): the quick MTBF sweep runs DHP and
# every baseline through the session facade under seeded fault traces.
# The bench itself exits non-zero if any of its three invariants break:
#   1. zero-fault (quiet-injector) goodput is bit-identical to a session
#      with no injector at all;
#   2. the same quiet run on the discrete-event kernel
#      (within_step_faults) is ALSO bit-identical — the event queue is a
#      pure re-ordering of the same arithmetic;
#   3. a scripted mid-wave RankFailure charges strictly less lost work
#      on the event kernel (partial-wave re-execution) than the boundary
#      path's whole-step replay.
cargo bench --bench resilience -- --quick

# Cluster-day gate (ISSUE-10): replays a seeded multi-tenant job trace
# through every allocator-policy × session-scheduler cell on ONE shared
# mesh. The bench exits non-zero on its own if either invariant breaks:
#   1. every cell replays digest- and byte-identically (the shared
#      virtual clock's (time, job_id) discipline);
#   2. on the pinned departure trace, the re-admitted queued job's
#      goodput under best-fit beats first-fit by >5% (whole-node vs
#      cross-node grant).
cargo bench --bench cluster_day -- --quick

echo
echo "=== BENCH_solver_micro.json ==="
cat BENCH_solver_micro.json

echo
echo "=== BENCH_resilience.json ==="
cat BENCH_resilience.json

echo
echo "=== BENCH_cluster_day.json ==="
cat BENCH_cluster_day.json

# ISSUE-8 record-shape gate: the resilience record must carry the
# event-kernel cells (within_step=true rows with a lost_work_s field)
# and both new gate verdicts — a record without them means the bench
# silently regressed to the boundary-only sweep.
echo
python3 - BENCH_resilience.json <<'PYEOF'
import json
import sys

with open(sys.argv[1]) as f:
    doc = json.load(f)

failed = False
for flag in ("zero_drift_ok", "within_step_zero_drift_ok", "mid_wave_charges_less_ok"):
    if doc.get(flag) is not True:
        print(f"[bench-resilience] FAIL: gate flag {flag!r} missing or false")
        failed = True
cells = doc.get("cells", [])
ws = [c for c in cells if c.get("within_step") is True]
if not ws:
    print("[bench-resilience] FAIL: no within_step=true cells in the record")
    failed = True
if any("lost_work_s" not in c for c in cells):
    print("[bench-resilience] FAIL: cells missing lost_work_s")
    failed = True
if not failed:
    print(f"[bench-resilience] OK: {len(ws)}/{len(cells)} event-kernel cells, all gates green")
sys.exit(1 if failed else 0)
PYEOF

# ISSUE-10 record-shape gate: the cluster-day record must carry both
# allocator policies with utilization and SLO cells (queue wait,
# completions, goodput) for every policy × scheduler cell, plus both
# gate verdicts — a record without them means the bench silently
# dropped a cell or stopped measuring the SLOs.
echo
python3 - BENCH_cluster_day.json <<'PYEOF'
import json
import sys

with open(sys.argv[1]) as f:
    doc = json.load(f)

failed = False
for flag in ("determinism_ok", "departure_scenario_ok"):
    if doc.get(flag) is not True:
        print(f"[bench-cluster-day] FAIL: gate flag {flag!r} missing or false")
        failed = True
SLO_FIELDS = (
    "mean_utilization",
    "mean_fragmentation",
    "mean_queue_wait_steps",
    "completed_jobs",
    "total_goodput_steps_per_s",
)
for table in ("departure_cells", "day_cells"):
    cells = doc.get(table, [])
    policies = {c.get("alloc_policy") for c in cells}
    if not {"first-fit", "best-fit"} <= policies:
        print(f"[bench-cluster-day] FAIL: {table} must cover both allocator "
              f"policies, got {sorted(p for p in policies if p)}")
        failed = True
    for c in cells:
        missing = [k for k in SLO_FIELDS if k not in c]
        if missing:
            print(f"[bench-cluster-day] FAIL: {table} cell "
                  f"{c.get('alloc_policy')}/{c.get('scheduler')} missing {missing}")
            failed = True
ff = doc.get("queued_job_goodput_first_fit", 0)
bf = doc.get("queued_job_goodput_best_fit", 0)
if not (isinstance(ff, (int, float)) and isinstance(bf, (int, float)) and bf > ff):
    print(f"[bench-cluster-day] FAIL: queued-job goodput best-fit {bf!r} "
          f"must exceed first-fit {ff!r}")
    failed = True
if not failed:
    n = len(doc.get("departure_cells", [])) + len(doc.get("day_cells", []))
    print(f"[bench-cluster-day] OK: {n} cells, both policies, SLO fields present, gates green")
sys.exit(1 if failed else 0)
PYEOF

# ISSUE-7 scale-tier gate: the 1024/4096-replica cases must exist (a
# silently dropped case would read as "still fast"), and the npus=1024
# case is scored against the paper's 1 ms solver budget on p90 tail
# latency. Budget verdict is warn-only until a baseline is committed
# (quick-mode reps on a contended CI box are noisy); with a committed
# baseline it fails the gate.
echo
python3 - BENCH_solver_micro.json "$BASELINE" <<'PYEOF'
import json
import os
import sys

REQUIRED = [
    "schedule_gbs2048_npus1024",
    "schedule_gbs8192_npus4096",
    # ISSUE-9: the steady-state correlated-stream case (cross-step
    # solver reuse). Its _hit/_warm/_coldref sub-cases carry the
    # cold-vs-steady-state comparison.
    "schedule_steady_stream_npus1024",
]
BUDGET_CASE = "schedule_gbs2048_npus1024"
BUDGET_MS = 1.0

fresh_path, baseline_path = sys.argv[1], sys.argv[2]
with open(fresh_path) as f:
    cases = json.load(f)["cases"]

failed = False
for name in REQUIRED:
    if name not in cases:
        print(f"[bench-scale] FAIL: required case {name!r} missing from {fresh_path}")
        failed = True
if failed:
    sys.exit(1)

p90 = cases[BUDGET_CASE].get("p90_ms", cases[BUDGET_CASE]["mean_ms"])
gated = os.path.exists(baseline_path)
verdict = "PASS" if p90 <= BUDGET_MS else ("FAIL" if gated else "WARN")
print(f"[bench-scale] {BUDGET_CASE}: p90 {p90:.3f} ms vs {BUDGET_MS:.1f} ms budget  {verdict}"
      + ("" if gated else "  (warn-only: no committed baseline yet)"))
sys.exit(1 if verdict == "FAIL" else 0)
PYEOF

if [[ "$COMPARE" == "1" ]]; then
    if [[ ! -f "$BASELINE" ]]; then
        cp BENCH_solver_micro.json "$BASELINE"
        echo
        echo "[bench-compare] no baseline found — seeded $BASELINE from this run."
        echo "[bench-compare] commit it to activate the regression gate."
        exit 0
    fi
    echo
    python3 - "$BASELINE" BENCH_solver_micro.json <<'PYEOF'
import json
import sys

GATE_CASE = "schedule_gbs512_npus64"
THRESHOLD = 0.10  # fail the gate case on >10% mean regression

baseline_path, fresh_path = sys.argv[1], sys.argv[2]
with open(baseline_path) as f:
    base = json.load(f)["cases"]
with open(fresh_path) as f:
    fresh = json.load(f)["cases"]

failed = False
shared = sorted(set(base) & set(fresh))
if not shared:
    print("[bench-compare] no shared cases between baseline and fresh run")
    sys.exit(1)
print(f"[bench-compare] baseline {baseline_path} vs fresh {fresh_path}")
for name in shared:
    b, f = base[name]["mean_ms"], fresh[name]["mean_ms"]
    if b <= 0:
        # A zero/negative baseline is corrupt; never let it disarm the gate.
        print(f"  {name:<44} invalid baseline mean_ms={b}")
        if name == GATE_CASE:
            failed = True
        continue
    delta = (f - b) / b
    tag = "ok"
    if delta > THRESHOLD:
        if name == GATE_CASE:
            tag = "FAIL (gate)"
            failed = True
        else:
            tag = "warn"
    print(f"  {name:<44} {b:>10.3f} -> {f:>10.3f} ms  ({delta:+7.1%})  {tag}")
missing = sorted(set(base) - set(fresh))
if missing:
    print(f"[bench-compare] cases missing from fresh run: {missing}")
if GATE_CASE not in shared:
    print(f"[bench-compare] gate case {GATE_CASE!r} not present in both records")
    failed = True
sys.exit(1 if failed else 0)
PYEOF
fi
