"""Ring / context-parallel numerics: chunked attention == full attention.

This is the correctness foundation of DHP's central relaxation — arbitrary
INTEGER CP degrees (not just powers of two). If attention over KV chunks
merged with online-softmax state equals monolithic attention for every chunk
count d, then a CP group of any degree d computes the exact same result as a
single device, and the scheduler is free to pick d from the full integer
range (paper §4.1).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    ring_attention_finalize,
    ring_attention_step,
)
from compile.kernels.ref import attention_ref, chunked_attention_ref


def _rand_qkv(key, B, H, L, D):
    ks = jax.random.split(key, 3)
    return [jax.random.normal(k, (B, H, L, D), jnp.float32) for k in ks]


# Non-power-of-two degrees are the paper's headline relaxation.
@pytest.mark.parametrize("nc", [1, 2, 3, 4, 5, 6, 7, 8])
@pytest.mark.parametrize("causal", [True, False])
def test_chunked_ref_matches_full(nc, causal):
    L = 840  # divisible by 1..8
    q, k, v = _rand_qkv(jax.random.PRNGKey(nc), 1, 2, L, 16)
    out = chunked_attention_ref(q, k, v, num_chunks=nc, causal=causal)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=3e-5)


def _run_ring(q, k, v, boundaries, causal):
    """Drive ring_attention_step across arbitrary chunk boundaries."""
    B, H, L, D = q.shape
    m = jnp.full((B, H, L, 1), -1e30, jnp.float32)
    l = jnp.zeros((B, H, L, 1), jnp.float32)
    acc = jnp.zeros((B, H, L, D), jnp.float32)
    for start, end in zip(boundaries[:-1], boundaries[1:]):
        m, l, acc = ring_attention_step(
            q, k[:, :, start:end], v[:, :, start:end], m, l, acc,
            chunk_start=start, causal=causal,
        )
    return ring_attention_finalize(m, l, acc)


@pytest.mark.parametrize("causal", [True, False])
def test_ring_steps_uneven_chunks(causal):
    """Ring state merging is exact even for UNEVEN chunk boundaries
    (what a CP group sees when the sequence does not divide evenly)."""
    L = 200
    q, k, v = _rand_qkv(jax.random.PRNGKey(0), 1, 2, L, 16)
    out = _run_ring(q, k, v, [0, 37, 64, 150, 200], causal)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=3e-5)


def test_ring_chunk_order_invariance_full_mask():
    """With a full mask, the ring may fold chunks in any order."""
    L = 128
    q, k, v = _rand_qkv(jax.random.PRNGKey(1), 1, 1, L, 16)
    chunks = [(0, 32), (32, 64), (64, 96), (96, 128)]
    ref = attention_ref(q, k, v, causal=False)
    for order in [(0, 1, 2, 3), (3, 2, 1, 0), (2, 0, 3, 1)]:
        B, H = 1, 1
        m = jnp.full((B, H, L, 1), -1e30, jnp.float32)
        l = jnp.zeros((B, H, L, 1), jnp.float32)
        acc = jnp.zeros((B, H, L, 16), jnp.float32)
        for i in order:
            s, e = chunks[i]
            m, l, acc = ring_attention_step(
                q, k[:, :, s:e], v[:, :, s:e], m, l, acc,
                chunk_start=s, causal=False,
            )
        out = ring_attention_finalize(m, l, acc)
        np.testing.assert_allclose(out, ref, atol=3e-5, rtol=3e-5)


def test_ring_single_chunk_is_identity_path():
    L = 64
    q, k, v = _rand_qkv(jax.random.PRNGKey(2), 2, 2, L, 8)
    out = _run_ring(q, k, v, [0, L], causal=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=3e-5)


@settings(max_examples=20, deadline=None)
@given(
    nc=st.integers(1, 10),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_ring_hypothesis_any_degree(nc, causal, seed):
    """Property: for ANY integer chunk count (CP degree), chunked == full."""
    L = 2520 // 4  # 630, divisible by 1,2,3,5,6,7,9,10 — pad otherwise
    if L % nc:
        # Pad L up to a multiple of nc to emulate the scheduler's padding.
        L = ((L // nc) + 1) * nc
    q, k, v = _rand_qkv(jax.random.PRNGKey(seed), 1, 1, L, 8)
    out = chunked_attention_ref(q, k, v, num_chunks=nc, causal=causal)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, ref, atol=5e-5, rtol=5e-5)
