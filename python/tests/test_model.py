"""L2 model tests: shapes, loss behaviour, freezing, flat-param round trip."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = M.TINY
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    vis, tok, tgt = M.example_batch(cfg, 2, 16, 48)
    return cfg, params, vis, tok, tgt


def test_forward_shapes(tiny_setup):
    cfg, params, vis, tok, tgt = tiny_setup
    logits = M.forward(params, cfg, vis, tok)
    assert logits.shape == (2, 48, cfg.vocab)


def test_vision_encoder_shapes(tiny_setup):
    cfg, params, vis, *_ = tiny_setup
    hv = M.encode_vision(params, cfg, vis)
    assert hv.shape == (2, 16, cfg.hidden)


def test_loss_finite_and_near_uniform_at_init(tiny_setup):
    cfg, params, vis, tok, tgt = tiny_setup
    loss = M.loss_fn(params, cfg, vis, tok, tgt)
    assert bool(jnp.isfinite(loss))
    # Tied-embedding init is near-uniform: loss ~ log(vocab).
    assert abs(float(loss) - np.log(cfg.vocab)) < 1.0


def test_param_count_tiny_and_e2e():
    assert M.param_count(M.TINY) < 1_000_000
    e2e = M.param_count(M.E2E_100M)
    assert 80_000_000 < e2e < 120_000_000, f"~100M target, got {e2e}"


def test_flat_roundtrip(tiny_setup):
    cfg, params, *_ = tiny_setup
    flat, unravel = M.flatten_params(params)
    back = unravel(flat)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        np.testing.assert_array_equal(a, b)


def test_loss_decreases_under_sgd(tiny_setup):
    cfg, params, vis, tok, tgt = tiny_setup
    flat0, fwd_loss, grad_step = M.make_flat_fns(cfg)
    step = jax.jit(grad_step)
    flat = flat0
    l0, g = step(flat, vis, tok, tgt)
    for _ in range(8):
        loss, g = step(flat, vis, tok, tgt)
        flat = flat - 0.5 * g
    l_end, _ = step(flat, vis, tok, tgt)
    assert float(l_end) < float(l0) - 0.1, (float(l0), float(l_end))


def test_freeze_vision_zeroes_vision_grads():
    cfg = M.TINY
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    flat, unravel = M.flatten_params(params)
    vis, tok, tgt = M.example_batch(cfg, 1, 16, 48)

    _, _, grad_frozen = M.make_flat_fns(cfg, key, freeze_vision=True)
    _, grads = jax.jit(grad_frozen)(flat, vis, tok, tgt)
    gtree = unravel(grads)
    # All vision-side grads must be exactly zero...
    for leaf in jax.tree.leaves(
        {k: gtree[k] for k in ("patch_embed", "vision_blocks", "connector")}
    ):
        np.testing.assert_array_equal(leaf, jnp.zeros_like(leaf))
    # ...while the LM still receives gradient.
    lm_norm = sum(
        float(jnp.abs(l).sum()) for l in jax.tree.leaves(gtree["blocks"])
    )
    assert lm_norm > 0


def test_grad_step_matches_value_and_grad(tiny_setup):
    cfg, params, vis, tok, tgt = tiny_setup
    flat0, fwd_loss, grad_step = M.make_flat_fns(cfg)
    loss1, grads = jax.jit(grad_step)(flat0, vis, tok, tgt)
    loss2 = jax.jit(fwd_loss)(flat0, vis, tok, tgt)
    np.testing.assert_allclose(float(loss1), float(loss2), rtol=1e-6)
    assert grads.shape == flat0.shape


def test_different_batch_entries_independent(tiny_setup):
    """Per-sequence isolation: changing sample 1 must not change sample 0's
    logits (no cross-batch leakage through attention)."""
    cfg, params, vis, tok, tgt = tiny_setup
    logits_a = M.forward(params, cfg, vis, tok)
    vis2 = vis.at[1].set(vis[1] * 2.0 + 1.0)
    tok2 = tok.at[1].set((tok[1] + 7) % cfg.vocab)
    logits_b = M.forward(params, cfg, vis2, tok2)
    np.testing.assert_allclose(
        logits_a[0], logits_b[0], atol=1e-5, rtol=1e-5
    )
    assert not np.allclose(logits_a[1], logits_b[1], atol=1e-3)


def test_causal_lm_future_text_does_not_leak(tiny_setup):
    """Changing a future text token must not affect earlier text logits."""
    cfg, params, vis, tok, tgt = tiny_setup
    logits_a = M.forward(params, cfg, vis, tok)
    tok2 = tok.at[:, -1].set((tok[:, -1] + 5) % cfg.vocab)
    logits_b = M.forward(params, cfg, vis, tok2)
    np.testing.assert_allclose(
        logits_a[:, :-1], logits_b[:, :-1], atol=1e-5, rtol=1e-5
    )


def test_vision_tokens_visible_to_text(tiny_setup):
    """Text logits must depend on vision input (the multimodal path)."""
    cfg, params, vis, tok, tgt = tiny_setup
    logits_a = M.forward(params, cfg, vis, tok)
    logits_b = M.forward(params, cfg, vis * 0.0, tok)
    assert not np.allclose(logits_a, logits_b, atol=1e-3)
