"""L1 correctness: Pallas flash-attention kernel vs the pure-jnp oracle.

This is the CORE kernel correctness signal: exact-shape cases, hypothesis
sweeps over shapes/dtypes, and mask-mode coverage (causal eta=0 vs full
eta=1).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import flash_attention
from compile.kernels.ref import attention_ref, mask_efficiency


def _rand_qkv(key, B, H, L, D, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return [jax.random.normal(k, (B, H, L, D), dtype) for k in ks]


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("L", [64, 128, 256])
def test_flash_matches_ref(causal, L):
    q, k, v = _rand_qkv(jax.random.PRNGKey(L), 2, 3, L, 32)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("block", [32, 64, 128])
def test_block_size_invariance(block):
    """Output must not depend on the VMEM tile decomposition."""
    q, k, v = _rand_qkv(jax.random.PRNGKey(7), 1, 2, 256, 16)
    ref = flash_attention(q, k, v, causal=True, block_q=128, block_k=128)
    out = flash_attention(q, k, v, causal=True, block_q=block, block_k=block)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_rectangular_blocks():
    q, k, v = _rand_qkv(jax.random.PRNGKey(9), 1, 1, 128, 32)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=64)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_short_sequence_degrades_blocks():
    """L smaller than the default 128 tile must still work."""
    q, k, v = _rand_qkv(jax.random.PRNGKey(3), 1, 2, 32, 16)
    out = flash_attention(q, k, v, causal=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_indivisible_length_fits_blocks():
    """Requested blocks not dividing L are shrunk to the largest divisor."""
    q, k, v = _rand_qkv(jax.random.PRNGKey(4), 1, 1, 96, 16)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_causal_first_row_is_v0():
    """Position 0 attends only to key 0 under the causal mask."""
    q, k, v = _rand_qkv(jax.random.PRNGKey(5), 1, 1, 64, 8)
    out = flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(out[0, 0, 0], v[0, 0, 0], atol=1e-5, rtol=1e-5)


def test_full_mask_is_permutation_equivariant_in_keys():
    """With a full mask, permuting (K, V) jointly must not change output."""
    q, k, v = _rand_qkv(jax.random.PRNGKey(6), 1, 1, 64, 8)
    perm = jax.random.permutation(jax.random.PRNGKey(0), 64)
    out1 = flash_attention(q, k, v, causal=False)
    out2 = flash_attention(q, k[:, :, perm], v[:, :, perm], causal=False)
    np.testing.assert_allclose(out1, out2, atol=2e-5, rtol=2e-5)


def test_uniform_values_passthrough():
    """If V is constant, attention output equals that constant exactly."""
    q, k, _ = _rand_qkv(jax.random.PRNGKey(8), 1, 2, 64, 16)
    v = jnp.full((1, 2, 64, 16), 3.5, jnp.float32)
    for causal in (True, False):
        out = flash_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(out, v, atol=1e-5, rtol=1e-5)


def test_scale_extreme_logits_stable():
    """Online softmax must survive large-magnitude logits (no inf/nan)."""
    q, k, v = _rand_qkv(jax.random.PRNGKey(10), 1, 1, 64, 16)
    out = flash_attention(q * 100.0, k * 100.0, v, causal=True)
    assert bool(jnp.isfinite(out).all())
    ref = attention_ref(q * 100.0, k * 100.0, v, causal=True)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


def test_mask_efficiency_factor():
    assert mask_efficiency(causal=True) == 0.0
    assert mask_efficiency(causal=False) == 1.0


@settings(max_examples=25, deadline=None)
@given(
    B=st.integers(1, 3),
    H=st.integers(1, 4),
    log_l=st.integers(4, 8),  # L in {16..256}
    log_d=st.integers(3, 6),  # D in {8..64}
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_hypothesis_f32(B, H, log_l, log_d, causal, seed):
    L, D = 2**log_l, 2**log_d
    q, k, v = _rand_qkv(jax.random.PRNGKey(seed), B, H, L, D)
    blk = min(64, L)
    out = flash_attention(q, k, v, causal=causal, block_q=blk, block_k=blk)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, ref, atol=5e-5, rtol=5e-5)


@settings(max_examples=8, deadline=None)
@given(
    log_l=st.integers(5, 7),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_hypothesis_bf16(log_l, causal, seed):
    """bf16 inputs: accumulate in f32, compare against the f32 oracle
    with bf16-scale tolerance."""
    L = 2**log_l
    q, k, v = _rand_qkv(jax.random.PRNGKey(seed), 1, 2, L, 32, jnp.bfloat16)
    blk = min(64, L)
    out = flash_attention(q, k, v, causal=causal, block_q=blk, block_k=blk)
    ref = attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        causal=causal,
    )
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref, atol=3e-2, rtol=3e-2
    )
