"""AOT export tests: HLO text emission, manifest integrity, bucket shapes."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M


def test_bucket_shape_quarters():
    for L in (128, 256, 512):
        Lv, Lt = aot.bucket_shape(L)
        assert Lv + Lt == L
        assert Lv == L // 4


@pytest.fixture(scope="module")
def tiny_hlo():
    cfg = M.TINY
    flat0, fwd_loss, grad_step = M.make_flat_fns(cfg)
    sp = aot.specs_for(cfg, flat0.shape[0], 2, 16, 48)
    return aot.lower_fn(grad_step, *sp)


def test_hlo_text_structure(tiny_hlo):
    assert "ENTRY" in tiny_hlo
    assert "HloModule" in tiny_hlo
    # grad_step returns (loss, grads): a 2-tuple root.
    assert "f32[]" in tiny_hlo  # scalar loss appears


def test_hlo_text_has_no_serialized_proto_markers(tiny_hlo):
    # Text format, parseable: first line is the module header.
    assert tiny_hlo.lstrip().startswith("HloModule")


def test_hlo_parameter_count(tiny_hlo):
    # Four entry parameters (flat_params, vis, tok, tgt) in the layout.
    layout = tiny_hlo[: tiny_hlo.index("\n")]
    assert "entry_computation_layout" in layout
    assert layout.count("f32") + layout.count("s32") >= 4
    assert "f32[146752]" in tiny_hlo  # tiny flat param vector
    # grad_step root is a (loss, grads) tuple.
    assert "(f32[], f32[146752]" in tiny_hlo


def test_export_model_writes_artifacts(tmp_path):
    manifest = {"artifacts": {}}
    aot.export_model(
        "model", M.TINY, str(tmp_path), manifest,
        B=2, L=64, grad=True, fwd=False, params_bin=True,
    )
    assert (tmp_path / "model.hlo.txt").exists()
    assert (tmp_path / "model_params.f32").exists()
    entry = manifest["artifacts"]["model.hlo.txt"]
    assert entry["kind"] == "grad_step"
    assert entry["param_count"] == 146752
    assert entry["seq_vision"] == 16 and entry["seq_text"] == 48
    psize = os.path.getsize(tmp_path / "model_params.f32")
    assert psize == entry["param_count"] * 4


def test_frozen_vision_artifact_differs(tmp_path):
    m1, m2 = {"artifacts": {}}, {"artifacts": {}}
    aot.export_model("a", M.TINY, str(tmp_path), m1, B=1, L=64,
                     grad=True, fwd=False, params_bin=False)
    aot.export_model("b", M.TINY, str(tmp_path), m2, B=1, L=64,
                     grad=True, fwd=False, params_bin=False,
                     freeze_vision=True)
    t1 = (tmp_path / "a.hlo.txt").read_text()
    t2 = (tmp_path / "b.hlo.txt").read_text()
    # The frozen graph omits vision backward ops — strictly smaller.
    assert len(t2) < len(t1)
