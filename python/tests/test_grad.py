"""Backward-pass correctness: the custom VJP of the Pallas attention entry
point must match jax.grad of the pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention
from compile.kernels.ref import attention_ref


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


def _grads(fn, q, k, v, causal):
    def loss(q, k, v):
        out = fn(q, k, v, causal)
        return (out * jnp.sin(jnp.arange(out.size).reshape(out.shape))).sum()

    return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)


def _ref_fn(q, k, v, causal):
    return attention_ref(q, k, v, causal=causal)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("L", [64, 128])
def test_attention_vjp_matches_ref(causal, L):
    ks = jax.random.split(jax.random.PRNGKey(L), 3)
    q, k, v = [_rand(kk, (1, 2, L, 16)) for kk in ks]
    gq, gk, gv = _grads(attention, q, k, v, causal)
    rq, rk, rv = _grads(_ref_fn, q, k, v, causal)
    np.testing.assert_allclose(gq, rq, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(gk, rk, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(gv, rv, atol=1e-4, rtol=1e-4)


def test_attention_value_matches_kernel_not_ref_path():
    """Forward of the custom-vjp wrapper is the Pallas kernel itself."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = [_rand(kk, (1, 1, 64, 16)) for kk in ks]
    out = attention(q, k, v, True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    log_l=st.integers(5, 7),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_vjp_hypothesis(log_l, causal, seed):
    L = 2**log_l
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q, k, v = [_rand(kk, (1, 1, L, 8)) for kk in ks]
    gq, gk, gv = _grads(attention, q, k, v, causal)
    rq, rk, rv = _grads(_ref_fn, q, k, v, causal)
    for g, r in [(gq, rq), (gk, rk), (gv, rv)]:
        np.testing.assert_allclose(g, r, atol=2e-4, rtol=2e-4)
