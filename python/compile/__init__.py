"""Build-time compile package: L2 JAX model + L1 Pallas kernels + AOT export.

Nothing in this package runs on the training request path — `aot.py` lowers
everything to HLO text once (`make artifacts`) and the Rust coordinator
executes the artifacts via PJRT.
"""
