"""L2: the JAX MLLM compute graph (forward / backward), calling L1 kernels.

Model shape follows the paper's abstraction (§3.1): a vision encoder with
FULL attention (eta=1) -> a connector MLP -> a causal language model (eta=0),
trained with next-token cross-entropy on the text region.

Parameters are exposed to the Rust coordinator as ONE flat f32 vector
(jax.flatten_util.ravel_pytree): `grad_step(flat, vis, tok, tgt)` returns
`(loss, flat_grads)`, so Layer 3 owns the optimizer (Adam in Rust) and the
PJRT artifact has a fixed, trivially-marshalled signature.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from .kernels import attention


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    """MLLM architecture configuration (cf. paper Table 5, scaled down)."""

    vocab: int = 8192
    hidden: int = 768  # LM hidden dim
    layers: int = 12  # LM transformer blocks
    heads: int = 12
    vision_hidden: int = 384
    vision_layers: int = 4
    vision_heads: int = 6
    patch_dim: int = 256  # raw patch feature dim fed to the vision encoder
    mlp_ratio: int = 4

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads

    @property
    def vision_head_dim(self) -> int:
        return self.vision_hidden // self.vision_heads

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


# ~98M parameters: the end-to-end validation model (EXPERIMENTS.md §E2E).
E2E_100M = ModelCfg()

# Small config for fast pytest / Rust integration-test artifacts.
TINY = ModelCfg(
    vocab=512,
    hidden=64,
    layers=2,
    heads=4,
    vision_hidden=32,
    vision_layers=1,
    vision_heads=2,
    patch_dim=16,
)

# Mid-size config used by the Rust Profiler to fit cost-model coefficients.
PROFILE = ModelCfg(
    vocab=2048,
    hidden=256,
    layers=4,
    heads=8,
    vision_hidden=128,
    vision_layers=2,
    vision_heads=4,
    patch_dim=64,
)

PRESETS = {"tiny": TINY, "profile": PROFILE, "e2e_100m": E2E_100M}


def _dense_init(key, shape, scale=None):
    if scale is None:
        scale = 1.0 / (shape[0] ** 0.5)
    return jax.random.normal(key, shape, jnp.float32) * scale


def _block_params(key, hidden: int, mlp_ratio: int):
    k = jax.random.split(key, 6)
    return {
        "ln1_g": jnp.ones((hidden,), jnp.float32),
        "ln1_b": jnp.zeros((hidden,), jnp.float32),
        "wqkv": _dense_init(k[0], (hidden, 3 * hidden)),
        "wo": _dense_init(k[1], (hidden, hidden)),
        "ln2_g": jnp.ones((hidden,), jnp.float32),
        "ln2_b": jnp.zeros((hidden,), jnp.float32),
        "w_up": _dense_init(k[2], (hidden, mlp_ratio * hidden)),
        "w_down": _dense_init(k[3], (mlp_ratio * hidden, hidden)),
    }


def init_params(cfg: ModelCfg, key: jax.Array):
    """Initialize the full MLLM parameter pytree."""
    keys = jax.random.split(key, 4 + cfg.vision_layers + cfg.layers)
    params = {
        "patch_embed": _dense_init(keys[0], (cfg.patch_dim, cfg.vision_hidden)),
        "vision_blocks": [
            _block_params(keys[4 + i], cfg.vision_hidden, cfg.mlp_ratio)
            for i in range(cfg.vision_layers)
        ],
        "vision_ln_g": jnp.ones((cfg.vision_hidden,), jnp.float32),
        "vision_ln_b": jnp.zeros((cfg.vision_hidden,), jnp.float32),
        "connector": _dense_init(keys[1], (cfg.vision_hidden, cfg.hidden)),
        "tok_embed": _dense_init(keys[2], (cfg.vocab, cfg.hidden), scale=0.02),
        "blocks": [
            _block_params(keys[4 + cfg.vision_layers + i], cfg.hidden, cfg.mlp_ratio)
            for i in range(cfg.layers)
        ],
        "final_ln_g": jnp.ones((cfg.hidden,), jnp.float32),
        "final_ln_b": jnp.zeros((cfg.hidden,), jnp.float32),
    }
    return params


def param_count(cfg: ModelCfg) -> int:
    params = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    return sum(int(jnp.prod(jnp.asarray(x.shape))) for x in jax.tree.leaves(params))


def flatten_params(params):
    """-> (flat f32 vector, unravel_fn)."""
    return ravel_pytree(params)


def _layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _sincos_pos(L: int, D: int):
    """Sinusoidal positions: length-agnostic, no parameters."""
    pos = jnp.arange(L, dtype=jnp.float32)[:, None]
    div = jnp.exp(
        jnp.arange(0, D, 2, dtype=jnp.float32) * (-jnp.log(10000.0) / D)
    )
    pe = jnp.zeros((L, D), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def _transformer_block(p, x, heads: int, causal: bool):
    """Pre-LN transformer block; attention is the L1 Pallas kernel."""
    B, L, D = x.shape
    hd = D // heads
    h = _layer_norm(x, p["ln1_g"], p["ln1_b"])
    qkv = h @ p["wqkv"]  # [B, L, 3D]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads_first(t):
        return t.reshape(B, L, heads, hd).transpose(0, 2, 1, 3)

    o = attention(heads_first(q), heads_first(k), heads_first(v), causal)
    o = o.transpose(0, 2, 1, 3).reshape(B, L, D)
    x = x + o @ p["wo"]
    h = _layer_norm(x, p["ln2_g"], p["ln2_b"])
    x = x + jax.nn.gelu(h @ p["w_up"]) @ p["w_down"]
    return x


def encode_vision(params, cfg: ModelCfg, vis):
    """Vision encoder: patch features -> LM-space visual tokens H_v.

    vis: [B, Lv, patch_dim] raw patch features. Full (non-causal)
    attention, i.e. the paper's eta=1 workload component.
    """
    x = vis @ params["patch_embed"]
    x = x + _sincos_pos(x.shape[1], x.shape[2])[None]
    for blk in params["vision_blocks"]:
        x = _transformer_block(blk, x, cfg.vision_heads, causal=False)
    x = _layer_norm(x, params["vision_ln_g"], params["vision_ln_b"])
    return x @ params["connector"]  # [B, Lv, hidden]


def forward(params, cfg: ModelCfg, vis, tok, *, freeze_vision: bool = False):
    """Full MLLM forward: H_in = [H_v ; H_q] -> causal LM -> logits.

    Returns logits over the TEXT positions only: [B, Lt, vocab].
    """
    hv = encode_vision(params, cfg, vis)
    if freeze_vision:
        # Fig. 4's training stage: the vision encoder runs forward but
        # receives no gradient (its backward cost leaves the workload).
        hv = jax.lax.stop_gradient(hv)
    hq = params["tok_embed"][tok]  # [B, Lt, hidden]
    x = jnp.concatenate([hv, hq], axis=1)
    x = x + _sincos_pos(x.shape[1], x.shape[2])[None]
    for blk in params["blocks"]:
        x = _transformer_block(blk, x, cfg.heads, causal=True)
    x = _layer_norm(x, params["final_ln_g"], params["final_ln_b"])
    text_h = x[:, hv.shape[1] :, :]
    return text_h @ params["tok_embed"].T  # tied softmax head


def loss_fn(params, cfg: ModelCfg, vis, tok, tgt, *, freeze_vision=False):
    """Mean next-token cross-entropy over text positions."""
    logits = forward(params, cfg, vis, tok, freeze_vision=freeze_vision)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return nll.mean()


def make_flat_fns(cfg: ModelCfg, key=None, *, freeze_vision: bool = False):
    """Build the flat-parameter-vector entry points for AOT export.

    Returns (flat0, fwd_loss, grad_step) where
      fwd_loss(flat, vis, tok, tgt) -> loss
      grad_step(flat, vis, tok, tgt) -> (loss, flat_grads)
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    flat0, unravel = flatten_params(params)

    def fwd_loss(flat, vis, tok, tgt):
        return loss_fn(
            unravel(flat), cfg, vis, tok, tgt, freeze_vision=freeze_vision
        )

    def grad_step(flat, vis, tok, tgt):
        loss, grads = jax.value_and_grad(fwd_loss)(flat, vis, tok, tgt)
        return loss, grads

    return flat0, fwd_loss, grad_step


def example_batch(cfg: ModelCfg, B: int, Lv: int, Lt: int, key=None):
    """Synthetic example inputs with the artifact signature shapes."""
    if key is None:
        key = jax.random.PRNGKey(1)
    k1, k2, k3 = jax.random.split(key, 3)
    vis = jax.random.normal(k1, (B, Lv, cfg.patch_dim), jnp.float32)
    tok = jax.random.randint(k2, (B, Lt), 0, cfg.vocab, jnp.int32)
    tgt = jax.random.randint(k3, (B, Lt), 0, cfg.vocab, jnp.int32)
    return vis, tok, tgt
