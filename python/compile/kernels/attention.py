"""L1 Pallas kernel: blocked flash attention for the DHP MLLM stack.

This is the compute hot-spot of the paper's workload (Eq. 8): softmax
attention over heterogeneous-length sequences, with either a causal mask
(language model, eta=0) or a full mask (vision encoder, eta=1).

TPU adaptation (DESIGN.md §Hardware-Adaptation): the CUDA flash-attention
schedule (threadblock tiles staged through shared memory) is re-expressed as
a Pallas grid over (batch*heads, q-blocks) with BlockSpecs staging
q/k/v tiles through VMEM; the two matmuls per kv-step are MXU-shaped
(block_q x head_dim @ head_dim x block_k, f32 accumulation). The online
softmax running state (m, l, acc) lives in VMEM scratch for the duration of
one q-block's kv sweep.

Always invoked with interpret=True in this repo: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers to plain HLO so the
kernel participates in the same AOT HLO-text artifact the Rust runtime loads.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30

# Default tile sizes. 128 is the MXU systolic-array edge; on real TPU these
# keep both matmuls MXU-shaped and the per-step VMEM footprint
# ~(2*Bk*D + Bq*D + Bq*Bk)*4B, far under the ~16 MiB VMEM budget for D<=256.
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _attn_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    *,
    causal: bool,
    scale: float,
    block_q: int,
    block_k: int,
    seq_len: int,
):
    """One (batch*head, q-block) grid step: sweep all kv blocks online.

    Refs are VMEM tiles selected by the BlockSpecs:
      q_ref: [block_q, D]   (this q tile)
      k_ref: [L, D]         (full K for this head; sliced per kv step)
      v_ref: [L, D]
      o_ref: [block_q, D]
    """
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale

    m = jnp.full((block_q, 1), NEG_INF, dtype=jnp.float32)
    l = jnp.zeros((block_q, 1), dtype=jnp.float32)
    acc = jnp.zeros(q.shape, dtype=jnp.float32)

    num_kv = seq_len // block_k
    # A python-level loop over kv blocks: unrolls at trace time, which is
    # what pallas interpret mode wants (grid is the outer loop). On real TPU
    # the causal path would bound this sweep at the diagonal (the eta=0
    # half-cost schedule); qi is a traced scalar here, so blocks above the
    # diagonal are where-masked instead — numerics are identical.
    for kj in range(num_kv):
        k_blk = k_ref[pl.dslice(kj * block_k, block_k), :].astype(jnp.float32)
        v_blk = v_ref[pl.dslice(kj * block_k, block_k), :].astype(jnp.float32)
        logits = q @ k_blk.T  # [block_q, block_k] — MXU-shaped
        if causal:
            q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)
            k_pos = kj * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = q_pos[:, None] >= k_pos[None, :]
            logits = jnp.where(mask, logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1, keepdims=True))
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1, keepdims=True)
        acc = acc * alpha + p @ v_blk  # second MXU matmul
        m = m_new

    o_ref[...] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    """Blocked flash attention via Pallas.

    Args:
      q, k, v: [batch, heads, seq, head_dim]. seq must be a multiple of the
        block sizes (the L2 model pads sequences to bucket boundaries, which
        is also what the DHP micro-batch planner produces).
      causal: LM path if True, vision-encoder full-attention path if False.
      block_q / block_k: VMEM tile sizes (MXU-aligned by default).
      interpret: must stay True for CPU PJRT execution (see module docstring).

    Returns:
      [batch, heads, seq, head_dim] attention output, dtype of q.
    """
    B, H, L, D = q.shape
    # Fit tile sizes to the sequence: the largest divisor of L not
    # exceeding the requested block (on real TPU the buckets are chosen
    # 128-aligned so this is the identity; interpret mode tolerates any).
    def fit(block: int) -> int:
        block = min(block, L)
        while L % block:
            block -= 1
        return max(block, 1)

    block_q = fit(block_q)
    block_k = fit(block_k)
    scale = 1.0 / (D**0.5)

    qf = q.reshape(B * H, L, D)
    kf = k.reshape(B * H, L, D)
    vf = v.reshape(B * H, L, D)

    kernel = functools.partial(
        _attn_kernel,
        causal=causal,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        seq_len=L,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * H, L // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, L, D), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, L, D), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, D), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, L, D), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, L, D)


def ring_attention_step(
    q: jax.Array,
    k_chunk: jax.Array,
    v_chunk: jax.Array,
    m: jax.Array,
    l: jax.Array,
    acc: jax.Array,
    *,
    chunk_start: int,
    causal: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One ring-CP step: fold a remote KV chunk into the running state.

    This is the per-hop computation each rank of a CP group performs when
    the ring rotates a KV chunk past it (paper §3.2 / Eq. 10's overlapped
    term). State layout matches `chunked_attention_ref`:
      m, l: [B, H, Lq, 1] running max / normalizer (f32)
      acc:  [B, H, Lq, D] unnormalized output accumulator (f32)

    Returns the updated (m, l, acc).
    """
    scale = 1.0 / (q.shape[-1] ** 0.5)
    logits = (
        jnp.einsum("bhqd,bhkd->bhqk", q, k_chunk).astype(jnp.float32) * scale
    )
    if causal:
        Lq, C = q.shape[-2], k_chunk.shape[-2]
        q_pos = jnp.arange(Lq)
        k_pos = chunk_start + jnp.arange(C)
        mask = q_pos[:, None] >= k_pos[None, :]
        logits = jnp.where(mask[None, None], logits, NEG_INF)
    m_new = jnp.maximum(m, logits.max(axis=-1, keepdims=True))
    p = jnp.exp(logits - m_new)
    alpha = jnp.exp(m - m_new)
    l_new = l * alpha + p.sum(axis=-1, keepdims=True)
    acc_new = acc * alpha + jnp.einsum(
        "bhqk,bhkd->bhqd", p, v_chunk.astype(jnp.float32)
    )
    return m_new, l_new, acc_new


def ring_attention_finalize(m, l, acc, dtype=jnp.float32):
    """Normalize the accumulated ring state into the attention output."""
    del m
    return (acc / jnp.maximum(l, 1e-30)).astype(dtype)
