"""L1 Pallas kernels for the DHP MLLM stack.

`attention` is the differentiable entry point the L2 model uses: Pallas
flash-attention forward (interpret=True) with a custom VJP whose backward
pass is the standard recompute formulation — pallas_call has no generic
autodiff rule, and the recompute backward keeps the AOT HLO self-contained.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .attention import (
    flash_attention,
    ring_attention_finalize,
    ring_attention_step,
)
from .ref import attention_ref, chunked_attention_ref, mask_efficiency

NEG_INF = -1e30


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def attention(q, k, v, causal: bool = True):
    """Differentiable flash attention (Pallas fwd, recompute bwd)."""
    return flash_attention(q, k, v, causal=causal)


def _attention_fwd(q, k, v, causal):
    out = flash_attention(q, k, v, causal=causal)
    return out, (q, k, v)


def _attention_bwd(causal, res, g):
    """Standard attention backward via recomputed probabilities.

    O(L^2) memory, which is fine at AOT bucket sizes; on real TPU this
    would be the blocked flash backward, but numerics are identical.
    """
    q, k, v = res
    L, D = q.shape[-2], q.shape[-1]
    scale = 1.0 / (D**0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((L, L), dtype=bool))
        logits = jnp.where(mask[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    gf = g.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    dv = jnp.einsum("bhqk,bhqd->bhkd", p, gf)
    dp = jnp.einsum("bhqd,bhkd->bhqk", gf, vf)
    # softmax backward: dlogits = p * (dp - sum_k p*dp)
    dlogits = p * (dp - (p * dp).sum(axis=-1, keepdims=True))
    dq = jnp.einsum("bhqk,bhkd->bhqd", dlogits, k.astype(jnp.float32)) * scale
    dk = jnp.einsum("bhqk,bhqd->bhkd", dlogits, q.astype(jnp.float32)) * scale
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


attention.defvjp(_attention_fwd, _attention_bwd)

__all__ = [
    "attention",
    "flash_attention",
    "attention_ref",
    "chunked_attention_ref",
    "mask_efficiency",
    "ring_attention_step",
    "ring_attention_finalize",
]
