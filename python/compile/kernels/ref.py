"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match the corresponding function here to float tolerance (pytest +
hypothesis sweeps in python/tests/). They are deliberately naive — O(L^2)
materialized attention — so there is no shared machinery with the kernels.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    """Naive softmax attention.

    Args:
      q, k, v: [batch, heads, seq, head_dim] arrays.
      causal: if True apply a lower-triangular mask (LM path, eta=0);
        if False use a full attention mask (vision-encoder path, eta=1).
      scale: optional override of the 1/sqrt(d) scaling.

    Returns:
      [batch, heads, seq, head_dim] attention output.
    """
    *_, L, D = q.shape
    if scale is None:
        scale = 1.0 / (D**0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        mask = jnp.tril(jnp.ones((L, L), dtype=bool))
        logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(v.dtype), v)


def chunked_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    num_chunks: int,
    causal: bool = True,
) -> jax.Array:
    """Ring/context-parallel attention reference.

    Computes the same result as `attention_ref` but with the KV sequence
    split into `num_chunks` contiguous chunks, merged with the online-softmax
    (m, l, acc) running state — the exact computation a CP group of degree
    `num_chunks` performs, one chunk per ring step. Proves that arbitrary
    integer CP degrees (non-power-of-two included) are numerically exact.
    """
    B, H, L, D = q.shape
    assert L % num_chunks == 0, "ref requires equal chunks"
    C = L // num_chunks
    scale = 1.0 / (D**0.5)

    m = jnp.full((B, H, L, 1), NEG_INF, dtype=jnp.float32)
    l = jnp.zeros((B, H, L, 1), dtype=jnp.float32)
    acc = jnp.zeros((B, H, L, D), dtype=jnp.float32)

    q_pos = jnp.arange(L)
    for c in range(num_chunks):
        k_c = k[:, :, c * C : (c + 1) * C]
        v_c = v[:, :, c * C : (c + 1) * C]
        logits = jnp.einsum("bhqd,bhkd->bhqk", q, k_c).astype(jnp.float32) * scale
        if causal:
            k_pos = jnp.arange(c * C, (c + 1) * C)
            mask = q_pos[:, None] >= k_pos[None, :]
            logits = jnp.where(mask[None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1, keepdims=True))
        # Rows with no visible keys in this chunk keep m at NEG_INF and
        # contribute zero weight.
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum(
            "bhqk,bhkd->bhqd", p, v_c.astype(jnp.float32)
        )
        m = m_new

    out = acc / jnp.maximum(l, 1e-30)
    return out.astype(q.dtype)


def mask_efficiency(causal: bool) -> float:
    """The paper's eta_k mask-efficiency factor (Eq. 8).

    Causal attention touches L^2/2 of the score matrix; full attention
    touches all L^2 entries — i.e. cost proportional to (1 + eta) with
    eta=0 for causal and eta=1 for full, matching 'full attention ...
    requires twice the computational effort' (paper §1).
    """
    return 1.0 if not causal else 0.0
