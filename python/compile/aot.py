"""AOT export: lower the L2 model (with L1 Pallas kernels) to HLO text.

HLO *text* — never `lowered.compiler_ir(...).serialize()` — is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids, which the xla_extension 0.5.1 the Rust `xla` crate links against
rejects (`proto.id() <= INT_MAX`). The text parser reassigns ids, so text
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts produced (under --out-dir, default ../artifacts):

  model.hlo.txt              tiny grad_step       (Rust integration tests)
  tiny_fwd.hlo.txt           tiny fwd_loss        (Rust integration tests)
  tiny_params.f32            tiny init flat params (raw little-endian f32)
  prof_fwd_L{L}.hlo.txt      profile-model fwd_loss at seq buckets
                             (the Rust Profiler times these to fit Eq. 8/9
                             coefficients against REAL executions)
  prof_grad_L{L}.hlo.txt     profile-model grad_step at seq buckets
  e2e_grad.hlo.txt           ~100M-param grad_step (end-to-end training)
  e2e_params.f32             ~100M init flat params
  manifest.json              shapes/sizes/configs for every artifact

Run via `make artifacts` (no-op when inputs are unchanged).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

# (Lv, Lt) per total-length bucket: vision tokens are 1/4 of the context,
# mirroring interleaved video-text batches.
def bucket_shape(L: int) -> tuple[int, int]:
    Lv = L // 4
    return Lv, L - Lv


PROFILE_BUCKETS = [128, 256, 384, 512, 768]
E2E_BUCKET = 256
E2E_BATCH = 2


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fn(fn, *specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*specs))


def specs_for(cfg: M.ModelCfg, P: int, B: int, Lv: int, Lt: int):
    return (
        jax.ShapeDtypeStruct((P,), jnp.float32),
        jax.ShapeDtypeStruct((B, Lv, cfg.patch_dim), jnp.float32),
        jax.ShapeDtypeStruct((B, Lt), jnp.int32),
        jax.ShapeDtypeStruct((B, Lt), jnp.int32),
    )


def write(path: str, text: str, manifest: dict, entry: dict):
    with open(path, "w") as f:
        f.write(text)
    entry["bytes"] = len(text)
    manifest["artifacts"][os.path.basename(path)] = entry
    print(f"  wrote {path} ({len(text) / 1e6:.2f} MB)")


def export_model(
    name: str,
    cfg: M.ModelCfg,
    out_dir: str,
    manifest: dict,
    *,
    B: int,
    L: int,
    grad: bool,
    fwd: bool,
    params_bin: bool,
    freeze_vision: bool = False,
    seed: int = 0,
):
    Lv, Lt = bucket_shape(L)
    flat0, fwd_loss, grad_step = M.make_flat_fns(
        cfg, jax.random.PRNGKey(seed), freeze_vision=freeze_vision
    )
    P = flat0.shape[0]
    meta = {
        "config": cfg.to_dict(),
        "param_count": P,
        "batch": B,
        "seq_total": L,
        "seq_vision": Lv,
        "seq_text": Lt,
        "freeze_vision": freeze_vision,
        "inputs": [
            {"name": "flat_params", "dtype": "f32", "shape": [P]},
            {"name": "vis", "dtype": "f32", "shape": [B, Lv, cfg.patch_dim]},
            {"name": "tok", "dtype": "i32", "shape": [B, Lt]},
            {"name": "tgt", "dtype": "i32", "shape": [B, Lt]},
        ],
    }
    sp = specs_for(cfg, P, B, Lv, Lt)
    if grad:
        write(
            os.path.join(out_dir, f"{name}.hlo.txt"),
            lower_fn(grad_step, *sp),
            manifest,
            {**meta, "kind": "grad_step", "outputs": ["loss f32[]", f"grads f32[{P}]"]},
        )
    if fwd:
        fname = f"{name}_fwd.hlo.txt" if grad else f"{name}.hlo.txt"
        write(
            os.path.join(out_dir, fname),
            lower_fn(fwd_loss, *sp),
            manifest,
            {**meta, "kind": "fwd_loss", "outputs": ["loss f32[]"]},
        )
    if params_bin:
        import numpy as np

        pfile = os.path.join(out_dir, f"{name.split('_')[0]}_params.f32")
        np.asarray(flat0, dtype="<f4").tofile(pfile)
        manifest["artifacts"][os.path.basename(pfile)] = {
            "kind": "params",
            "param_count": P,
            "bytes": P * 4,
        }
        print(f"  wrote {pfile} ({P * 4 / 1e6:.2f} MB, {P} params)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="single-artifact compat path")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--skip-e2e",
        action="store_true",
        help="skip the ~100M e2e artifact (slow to lower)",
    )
    args = ap.parse_args()

    out_dir = args.out_dir
    if args.out:  # Makefile passes --out artifacts/model.hlo.txt
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)
    manifest: dict = {"artifacts": {}}

    print("[aot] tiny model (tests)")
    export_model(
        "model", M.TINY, out_dir, manifest, B=2, L=64, grad=True, fwd=False,
        params_bin=False,
    )
    export_model(
        "tiny", M.TINY, out_dir, manifest, B=2, L=64, grad=False, fwd=True,
        params_bin=True,
    )

    print("[aot] profile model (cost-model calibration)")
    for L in PROFILE_BUCKETS:
        export_model(
            f"prof_fwd_L{L}", M.PROFILE, out_dir, manifest, B=1, L=L,
            grad=False, fwd=True, params_bin=(L == PROFILE_BUCKETS[0]),
        )
        export_model(
            f"prof_grad_L{L}", M.PROFILE, out_dir, manifest, B=1, L=L,
            grad=True, fwd=False, params_bin=False,
        )

    if not args.skip_e2e:
        print("[aot] e2e ~100M model")
        export_model(
            "e2e_grad", M.E2E_100M, out_dir, manifest, B=E2E_BATCH,
            L=E2E_BUCKET, grad=True, fwd=False, params_bin=True,
        )

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] manifest: {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
